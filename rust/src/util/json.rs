//! Minimal JSON parser + writer.
//!
//! The offline crate set has no `serde_json`; the runtime needs to read
//! `artifacts/manifest.json` and `artifacts/golden_vectors.json`, and the
//! bench harness writes result JSON. This is a small, strict (RFC 8259
//! subset: no comments, no trailing commas) recursive-descent parser.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access, `/`-separated.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('/') {
            cur = match cur {
                Json::Obj(m) => m.get(part)?,
                Json::Arr(v) => v.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64> (common case for golden vectors).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    pub fn as_i64_vec(&self) -> Option<Vec<i64>> {
        self.as_arr()?.iter().map(Json::as_i64).collect()
    }
}

/// Nesting bound: the parser is recursive-descent and parses untrusted
/// network bodies (HTTP server), so depth must be limited well below
/// thread stack exhaustion.
const MAX_DEPTH: u32 = 512;

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

/// How the top-level `"words"` field of a request body parsed (see
/// [`parse_request_words`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WordsField {
    /// No top-level `"words"` key (or the document is not an object).
    Absent,
    /// `"words"` is present but not an array.
    NotArray,
    /// `"words"` is an array but some element is not an exact integer.
    /// `len` is the total element count — the API layer checks batch
    /// capacity before element types, so the count must survive.
    NotInt { len: usize },
    /// `"words"` is an array of exact integers, appended to the sink.
    Ints { len: usize },
}

/// Parse a request document, streaming a top-level `"words"` integer
/// array directly into `sink` (appended; never cleared) instead of
/// building per-element [`Json`] nodes. The returned document carries
/// an empty placeholder array under `"words"`; the real words live in
/// the sink, described by the [`WordsField`]. Non-object documents and
/// malformed input behave exactly like [`parse`] — byte positions and
/// messages included — so the serving layer's error strings are
/// unchanged. This is the zero-copy request path (`server/api.rs`):
/// with a warm per-thread sink, decoding allocates nothing per word.
pub fn parse_request_words(
    input: &str,
    sink: &mut Vec<i64>,
) -> Result<(Json, WordsField), ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0, depth: 0 };
    p.skip_ws();
    let (v, field) = if p.peek() == Some(b'{') {
        // Same depth bookkeeping as `value()`'s `nested(object)`
        // (top level: 0 < MAX_DEPTH, no check needed).
        p.depth += 1;
        let r = p.object_intercept_words(sink);
        p.depth -= 1;
        r?
    } else {
        (p.value()?, WordsField::Absent)
    };
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok((v, field))
}

/// The serving layer's exact-integer criterion: a `Num` that is
/// integral and inside the window where f64 represents integers
/// exactly. Shared by [`parse_request_words`] and the API layer's
/// scalar fields so both agree on what counts as an integer.
pub fn exact_i64(v: &Json) -> Option<i64> {
    match v {
        Json::Num(n) if n.fract() == 0.0 && n.abs() <= 9e15 => Some(*n as i64),
        _ => None,
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: u32,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.nested(Parser::object),
            b'[' => self.nested(Parser::array),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    /// Run a container parser one nesting level deeper, bounded.
    fn nested(
        &mut self,
        f: fn(&mut Parser<'a>) -> Result<Json, ParseError>,
    ) -> Result<Json, ParseError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.depth += 1;
        let v = f(&mut *self);
        self.depth -= 1;
        v
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    /// `object()` with a top-level `"words"` interception (see
    /// [`parse_request_words`]): key order, duplicate-key last-wins and
    /// every error site match the plain parser.
    fn object_intercept_words(
        &mut self,
        sink: &mut Vec<i64>,
    ) -> Result<(Json, WordsField), ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        let mut field = WordsField::Absent;
        let words_start = sink.len();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok((Json::Obj(map), field));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = if key == "words" {
                // Duplicate key: last one wins (like the BTreeMap
                // insert below) — drop any earlier decode.
                sink.truncate(words_start);
                field = self.words_value(sink)?;
                Json::Arr(Vec::new())
            } else {
                self.value()?
            };
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok((Json::Obj(map), field));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    /// The value of a `"words"` key: integer arrays stream into `sink`;
    /// anything else is still fully consumed (so malformed documents
    /// keep their exact parse errors) and reported by kind.
    fn words_value(
        &mut self,
        sink: &mut Vec<i64>,
    ) -> Result<WordsField, ParseError> {
        if self.peek() != Some(b'[') {
            self.value()?;
            return Ok(WordsField::NotArray);
        }
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.depth += 1;
        let r = self.words_array(sink);
        self.depth -= 1;
        r
    }

    fn words_array(
        &mut self,
        sink: &mut Vec<i64>,
    ) -> Result<WordsField, ParseError> {
        self.expect(b'[')?;
        let start = sink.len();
        let mut ints = true;
        let mut len = 0usize;
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(WordsField::Ints { len: 0 });
        }
        loop {
            self.skip_ws();
            // Number literals build a heap-free `Json::Num`; only the
            // (error-path) non-number elements allocate.
            let v = self.value()?;
            len += 1;
            if ints {
                match exact_i64(&v) {
                    Some(w) => sink.push(w),
                    None => {
                        ints = false;
                        sink.truncate(start);
                    }
                }
            }
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(if ints {
                        WordsField::Ints { len }
                    } else {
                        WordsField::NotInt { len }
                    });
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let code = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                // High surrogate: combine with a following
                                // \uXXXX low surrogate (RFC 8259 §7).
                                self.low_surrogate_tail(code)?
                            } else if (0xDC00..0xE000).contains(&code) {
                                '\u{FFFD}' // lone low surrogate
                            } else {
                                char::from_u32(code).unwrap_or('\u{FFFD}')
                            };
                            s.push(ch);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| self.err("bad utf8"))?;
                        let st = std::str::from_utf8(bytes)
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(st);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    /// Four hex digits of a `\u` escape (cursor already past the `u`).
    fn hex4(&mut self) -> Result<u32, ParseError> {
        let hex = self
            .b
            .get(self.i..self.i + 4)
            .ok_or_else(|| self.err("bad \\u"))?;
        // from_str_radix tolerates a leading '+'; RFC 8259 requires
        // exactly four hex digits.
        if !hex.iter().all(u8::is_ascii_hexdigit) {
            return Err(self.err("bad \\u"));
        }
        let code = u32::from_str_radix(
            std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
            16,
        )
        .map_err(|_| self.err("bad \\u"))?;
        self.i += 4;
        Ok(code)
    }

    /// After a high surrogate `hi`, consume a `\uXXXX` low surrogate and
    /// combine; a lone high surrogate becomes U+FFFD (and whatever
    /// followed is re-parsed normally).
    fn low_surrogate_tail(&mut self, hi: u32) -> Result<char, ParseError> {
        if self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u') {
            let save = self.i;
            self.i += 2;
            let lo = self.hex4()?;
            if (0xDC00..0xE000).contains(&lo) {
                let code = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                return Ok(char::from_u32(code).unwrap_or('\u{FFFD}'));
            }
            // Not a low surrogate: rewind so the escape parses on its own.
            self.i = save;
        }
        Ok('\u{FFFD}')
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize a JSON value (compact).
pub fn write(v: &Json) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => {
                        out.push_str(&format!("\\u{:04x}", c as u32))
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(v) => {
            out.push('[');
            for (i, e) in v.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(e, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, e)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Json::Str(k.clone()), out);
                out.push(':');
                write_into(e, out);
            }
            out.push('}');
        }
    }
}

/// Append a raw i64 slice as a JSON array — the response-side zero-copy
/// helper (no per-element [`Json`] nodes). Byte-identical to writing
/// `Json::Arr` of in-range `Num`s.
pub fn write_i64_array(words: &[i64], out: &mut String) {
    use std::fmt::Write as _;
    out.push('[');
    for (i, w) in words.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{w}");
    }
    out.push(']');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path("a/2/b").unwrap().as_str(), Some("c"));
        assert_eq!(v.path("a/0").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("[1] x").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,true,null,"s\"x"],"m":{"n":-3}}"#;
        let v = parse(src).unwrap();
        let out = write(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn int_vec_helper() {
        let v = parse("[1, 2, 3]").unwrap();
        assert_eq!(v.as_i64_vec().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn surrogate_pairs_combine() {
        assert_eq!(parse("\"\\ud83d\\ude00\"").unwrap(), Json::Str("😀".into()));
        assert_eq!(
            parse("\"x\\uD83D\\uDE00!\"").unwrap(),
            Json::Str("x😀!".into())
        );
    }

    #[test]
    fn lone_surrogates_become_replacement() {
        assert_eq!(parse("\"\\ud83d\"").unwrap(), Json::Str("\u{FFFD}".into()));
        assert_eq!(parse("\"\\ude00\"").unwrap(), Json::Str("\u{FFFD}".into()));
        // High surrogate followed by a non-surrogate escape: the escape
        // must survive on its own.
        assert_eq!(
            parse("\"\\ud83d\\u0041\"").unwrap(),
            Json::Str("\u{FFFD}A".into())
        );
    }

    #[test]
    fn hostile_nesting_is_an_error_not_a_crash() {
        let bomb = "[".repeat(100_000);
        let err = parse(&bomb).unwrap_err();
        assert!(err.msg.contains("nesting too deep"), "{err}");
        // Well-formed documents inside the bound still parse.
        let deep = format!("{}1{}", "[".repeat(400), "]".repeat(400));
        assert!(parse(&deep).is_ok());
    }

    #[test]
    fn unicode_escape_requires_four_hex_digits() {
        assert!(parse("\"\\u+041\"").is_err()); // '+' is not a hex digit
        assert!(parse("\"\\u00 1\"").is_err());
        assert!(parse("\"\\u0041\"").is_ok());
    }

    #[test]
    fn request_words_stream_into_sink() {
        let mut sink = vec![7i64]; // pre-existing content must survive
        let (v, f) = parse_request_words(
            r#"{"model":"s3_12","words":[1, -2, 1e3]}"#,
            &mut sink,
        )
        .unwrap();
        assert_eq!(f, WordsField::Ints { len: 3 });
        assert_eq!(sink, vec![7, 1, -2, 1000]);
        assert_eq!(v.path("model").unwrap().as_str(), Some("s3_12"));
        // The document carries a placeholder, not the words.
        assert_eq!(v.get("words"), Some(&Json::Arr(Vec::new())));
    }

    #[test]
    fn request_words_kinds() {
        let mut s = Vec::new();
        let (_, f) = parse_request_words(r#"{"words": 5}"#, &mut s).unwrap();
        assert_eq!(f, WordsField::NotArray);
        let (_, f) =
            parse_request_words(r#"{"words": []}"#, &mut s).unwrap();
        assert_eq!(f, WordsField::Ints { len: 0 });
        let (_, f) =
            parse_request_words(r#"{"words": [1, 2.5, "x"]}"#, &mut s)
                .unwrap();
        assert_eq!(f, WordsField::NotInt { len: 3 });
        assert!(s.is_empty(), "non-integer arrays leave the sink clean");
        let (_, f) = parse_request_words(r#"{"x": 1}"#, &mut s).unwrap();
        assert_eq!(f, WordsField::Absent);
        let (_, f) = parse_request_words("[1, 2]", &mut s).unwrap();
        assert_eq!(f, WordsField::Absent);
    }

    #[test]
    fn request_words_duplicate_key_last_wins() {
        let mut s = Vec::new();
        let (_, f) = parse_request_words(
            r#"{"words":[1,2],"words":[9]}"#,
            &mut s,
        )
        .unwrap();
        assert_eq!(f, WordsField::Ints { len: 1 });
        assert_eq!(s, vec![9]);
    }

    #[test]
    fn request_words_errors_match_plain_parse() {
        for src in [
            r#"{"words":[1,}"#,
            r#"{"words":[1] extra"#,
            r#"{"words":"#,
            "{",
            "nope",
        ] {
            let mut s = Vec::new();
            let a = parse_request_words(src, &mut s).unwrap_err();
            let b = parse(src).unwrap_err();
            assert_eq!((a.pos, a.msg), (b.pos, b.msg), "{src}");
        }
    }

    #[test]
    fn i64_array_writer_matches_tree_writer() {
        let words = [0i64, 1, -1, 32767, -32768, 1 << 40];
        let mut fast = String::new();
        write_i64_array(&words, &mut fast);
        let tree =
            Json::Arr(words.iter().map(|&w| Json::Num(w as f64)).collect());
        assert_eq!(fast, write(&tree));
    }

    #[test]
    fn astral_and_control_roundtrip() {
        let ctl: String = (0u32..0x20).map(|c| char::from_u32(c).unwrap()).collect();
        for s in ["😀 \u{10FFFF}", ctl.as_str(), "\u{7F}\"\\/"] {
            let v = Json::Str(s.to_string());
            assert_eq!(parse(&write(&v)).unwrap(), v, "{s:?}");
        }
    }
}
