//! Minimal `anyhow`-style error type.
//!
//! The offline crate set has no `anyhow`; this provides the subset the
//! runtime needs: a message error constructed by [`crate::anyhow!`] /
//! [`crate::bail!`], a context chain added via the [`Context`] extension
//! trait, `{}` printing the outermost message and `{:#}` printing the
//! whole chain (`outer: ...: root`), exactly like `anyhow`'s alternate
//! formatting that the robustness tests assert on.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message error with a context chain. `chain[0]` is the outermost
/// (most recently attached) context; the last element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (the root cause).
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { chain: vec![msg.into()] }
    }

    /// Attach an outer context layer.
    pub fn context(mut self, msg: impl fmt::Display) -> Error {
        self.chain.insert(0, msg.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Debug mirrors the full chain so `unwrap()` panics are readable.
        write!(f, "{}", self.chain.join(": "))
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

/// Extension trait mirroring `anyhow::Context` for the error types that
/// actually flow through the runtime.
pub trait Context<T> {
    /// Wrap the error with a lazily-built context message.
    fn with_context<S: fmt::Display, F: FnOnce() -> S>(
        self,
        f: F,
    ) -> Result<T, Error>;

    /// Wrap the error with a fixed context message.
    fn context<S: fmt::Display>(self, msg: S) -> Result<T, Error>;
}

impl<T> Context<T> for Result<T, Error> {
    fn with_context<S: fmt::Display, F: FnOnce() -> S>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }

    fn context<S: fmt::Display>(self, msg: S) -> Result<T, Error> {
        self.map_err(|e| e.context(msg))
    }
}

impl<T> Context<T> for Result<T, std::io::Error> {
    fn with_context<S: fmt::Display, F: FnOnce() -> S>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e.to_string()).context(f()))
    }

    fn context<S: fmt::Display>(self, msg: S) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e.to_string()).context(msg))
    }
}

/// Construct an [`Error`] from a format string (`anyhow::anyhow!` shape).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with an [`Error`] (`anyhow::bail!` shape).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_display_is_outermost_only() {
        let e = Error::msg("root").context("outer");
        assert_eq!(format!("{e}"), "outer");
    }

    #[test]
    fn alternate_display_is_full_chain() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
    }

    #[test]
    fn io_context_chains() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "no such file",
        ));
        let e = r.with_context(|| "reading manifest").unwrap_err();
        let s = format!("{e:#}");
        assert!(s.starts_with("reading manifest: "), "{s}");
        assert!(s.contains("no such file"), "{s}");
    }

    #[test]
    fn macros_build_errors() {
        let e = crate::anyhow!("x = {}", 42);
        assert_eq!(format!("{e}"), "x = 42");
        fn inner() -> Result<()> {
            crate::bail!("boom {}", 1);
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "boom 1");
    }
}
