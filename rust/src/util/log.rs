//! Structured leveled JSON logging (zero-dep `tracing` stand-in).
//!
//! Every line is one JSON object on stderr:
//!
//! ```text
//! {"ts_ms":1731571200123,"level":"info","target":"cluster",
//!  "msg":"member died","peer":"127.0.0.1:8791"}
//! ```
//!
//! The level is read once from `TANHVF_LOG`
//! (`error|warn|info|debug`, default `info`); anything below the
//! configured level is dropped before any formatting work happens, so
//! disabled `debug` call sites cost one relaxed atomic load.
//!
//! Fields are flat string pairs — callers format numbers themselves.
//! Keys are written as-is (callers use plain identifiers); values are
//! JSON-escaped. `ts_ms` is wall-clock Unix milliseconds: log lines
//! are for operators correlating with the outside world, unlike trace
//! spans (`server::trace`) whose clock is virtualized under the
//! simulator.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use super::json::{self, Json};

/// Severity, ordered so that a numeric comparison implements "at least
/// as severe as".
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    fn parse(s: &str) -> Option<Level> {
        match s {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// Cached threshold: 0xff = not yet initialized from the environment.
static THRESHOLD: AtomicU8 = AtomicU8::new(0xff);

fn threshold() -> u8 {
    let t = THRESHOLD.load(Ordering::Relaxed);
    if t != 0xff {
        return t;
    }
    let level = std::env::var("TANHVF_LOG")
        .ok()
        .and_then(|v| Level::parse(&v))
        .unwrap_or(Level::Info);
    THRESHOLD.store(level as u8, Ordering::Relaxed);
    level as u8
}

/// Would a record at `level` be emitted? Lets callers skip expensive
/// field construction for disabled levels.
pub fn enabled(level: Level) -> bool {
    level as u8 <= threshold()
}

/// Override the threshold programmatically (tests; wins over the
/// environment for the rest of the process lifetime).
pub fn set_level(level: Level) {
    THRESHOLD.store(level as u8, Ordering::Relaxed);
}

fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Emit one structured record. Prefer the [`error`]/[`warn`]/[`info`]/
/// [`debug`] wrappers.
pub fn log(level: Level, target: &str, msg: &str, fields: &[(&str, String)]) {
    if !enabled(level) {
        return;
    }
    let mut line = String::with_capacity(96);
    line.push_str("{\"ts_ms\":");
    line.push_str(&now_ms().to_string());
    line.push_str(",\"level\":\"");
    line.push_str(level.name());
    line.push_str("\",\"target\":");
    line.push_str(&json::write(&Json::Str(target.to_string())));
    line.push_str(",\"msg\":");
    line.push_str(&json::write(&Json::Str(msg.to_string())));
    for (k, v) in fields {
        line.push(',');
        line.push_str(&json::write(&Json::Str((*k).to_string())));
        line.push(':');
        line.push_str(&json::write(&Json::Str(v.clone())));
    }
    line.push('}');
    // One eprintln per record: the write is a single syscall for
    // typical line lengths, so concurrent threads don't interleave.
    eprintln!("{line}");
}

pub fn error(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Error, target, msg, fields);
}

pub fn warn(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Warn, target, msg, fields);
}

pub fn info(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Info, target, msg, fields);
}

pub fn debug(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Debug, target, msg, fields);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_order_matches_severity() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn parse_rejects_unknown() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("verbose"), None);
    }

    #[test]
    fn threshold_gates_levels() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
    }
}
