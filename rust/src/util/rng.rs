//! Deterministic xoshiro256** PRNG.
//!
//! The offline crate set has no `rand`; this is the standard xoshiro256**
//! generator (Blackman & Vigna), more than adequate for workload
//! generation, property testing and synthetic data.

/// splitmix64 (Steele, Lea & Flood): a tiny, stateless-feeling mixer
/// whose every seed — including 0 — yields a full-period sequence.
/// Used standalone wherever a *cheap, trivially forkable* deterministic
/// stream is wanted (the cluster simulation derives one generator per
/// scenario from the schedule seed), and as the seeding stage of
/// [`Rng`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift reduction).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// True with probability `num/den`.
    #[inline]
    pub fn chance(&mut self, num: u64, den: u64) -> bool {
        self.below(den) < num
    }
}

/// xoshiro256** state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift reduction.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform i64 in `[lo, hi)`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_matches_reference_vector() {
        // First outputs for seed 1234567, from the published splitmix64
        // reference implementation.
        let mut sm = SplitMix64::new(1234567);
        assert_eq!(sm.next_u64(), 0x599ed017fb08fc85);
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = SplitMix64::new(9);
        for _ in 0..1000 {
            assert!(c.below(13) < 13);
        }
        let mut d = SplitMix64::new(3);
        let heads = (0..4000).filter(|_| d.chance(1, 4)).count();
        assert!((800..1200).contains(&heads), "chance(1/4): {heads}/4000");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_hits_endpoints_eventually() {
        let mut r = Rng::new(2);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..100_000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 2;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
