//! Order statistics shared by every latency reporter in the crate.
//!
//! Two pieces:
//!
//! * [`percentile`] — the nearest-rank (ceiling) percentile picker.
//!   Both the coordinator metrics and the load generator used to
//!   truncate `((len - 1) * q) as usize`, which rounds the rank *down*
//!   and systematically under-reports upper quantiles (p99 of 10
//!   samples read the 9th value, not the 10th). Nearest-rank is the
//!   textbook definition: the smallest value with at least `q` of the
//!   mass at or below it — never below the true quantile, exact at the
//!   sample points.
//! * [`Reservoir`] — a fixed-capacity ring of the newest samples, so a
//!   long-running server keeps O(capacity) memory no matter how many
//!   latencies it records.

/// Nearest-rank percentile of an ascending-sorted slice.
///
/// `q` is clamped to `[0, 1]`; an empty slice yields 0. For non-empty
/// data the rank is `ceil(q * n)` (minimum 1), so `q = 0.5` of
/// `[10, 20, 30, 40]` is 20, `q = 1.0` is always the maximum, and
/// `q = 0.99` of ten samples is the 10th value — not the 9th the old
/// truncating picker returned.
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let q = q.clamp(0.0, 1.0);
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.max(1).min(sorted.len()) - 1]
}

/// Fixed-capacity ring buffer of `u64` samples: pushing past capacity
/// overwrites the oldest sample in place (O(1), no reallocation), so
/// the memory footprint of a metrics sink is bounded for the life of
/// the process.
#[derive(Clone, Debug)]
pub struct Reservoir {
    buf: Vec<u64>,
    capacity: usize,
    /// Next write position once the ring has wrapped.
    head: usize,
    /// Total samples ever pushed (not capped).
    pushed: u64,
}

impl Reservoir {
    pub fn new(capacity: usize) -> Reservoir {
        Reservoir {
            buf: Vec::new(),
            capacity: capacity.max(1),
            head: 0,
            pushed: 0,
        }
    }

    /// Record one sample, evicting the oldest when full.
    pub fn push(&mut self, v: u64) {
        self.pushed += 1;
        if self.buf.len() < self.capacity {
            self.buf.push(v);
        } else {
            self.buf[self.head] = v;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    /// Samples currently held (<= capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total samples ever pushed, including evicted ones.
    pub fn total_pushed(&self) -> u64 {
        self.pushed
    }

    /// The held samples, unordered — a cheap clone so callers holding
    /// a lock around the reservoir can sort *outside* it.
    pub fn samples(&self) -> Vec<u64> {
        self.buf.clone()
    }

    /// The held samples, ascending — ready for [`percentile`].
    pub fn sorted(&self) -> Vec<u64> {
        let mut v = self.samples();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_pinned_on_known_distributions() {
        // 1..=100: nearest-rank pX is exactly X.
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.95), 95);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&v, 0.0), 1);

        // Ten samples: p99 must be the maximum (the old truncating
        // picker returned the 9th value here).
        let v: Vec<u64> = (1..=10).map(|i| i * 10).collect();
        assert_eq!(percentile(&v, 0.99), 100);
        assert_eq!(percentile(&v, 0.95), 100);
        assert_eq!(percentile(&v, 0.90), 90);
        assert_eq!(percentile(&v, 0.50), 50);

        // Odd count: the median is the middle element.
        assert_eq!(percentile(&[10, 20, 30, 40, 1000], 0.5), 30);
        assert_eq!(percentile(&[10, 20, 30, 40, 1000], 0.99), 1000);
    }

    #[test]
    fn percentile_edge_cases() {
        assert_eq!(percentile(&[], 0.99), 0);
        assert_eq!(percentile(&[7], 0.0), 7);
        assert_eq!(percentile(&[7], 1.0), 7);
        // Out-of-range q clamps instead of indexing out of bounds.
        assert_eq!(percentile(&[1, 2, 3], 2.0), 3);
        assert_eq!(percentile(&[1, 2, 3], -1.0), 1);
    }

    #[test]
    fn percentile_never_below_truncating_picker() {
        // The fix direction is monotone: nearest-rank is >= the old
        // truncated index for every (n, q).
        for n in [1usize, 2, 3, 7, 10, 50, 100, 997] {
            let v: Vec<u64> = (0..n as u64).collect();
            for q in [0.5, 0.9, 0.95, 0.99, 0.999] {
                let old = v[((n - 1) as f64 * q) as usize];
                assert!(
                    percentile(&v, q) >= old,
                    "n={n} q={q}: {} < {old}",
                    percentile(&v, q)
                );
            }
        }
    }

    #[test]
    fn reservoir_keeps_newest_and_stays_bounded() {
        let mut r = Reservoir::new(100);
        for i in 0..1000u64 {
            r.push(i);
        }
        assert_eq!(r.len(), 100);
        assert_eq!(r.total_pushed(), 1000);
        let s = r.sorted();
        // Exactly the newest 100 samples survive.
        assert_eq!(s, (900..1000).collect::<Vec<u64>>());
        assert_eq!(percentile(&s, 1.0), 999);
        assert_eq!(percentile(&s, 0.5), 949);
    }

    #[test]
    fn reservoir_below_capacity_is_lossless() {
        let mut r = Reservoir::new(8);
        for v in [5u64, 3, 9] {
            r.push(v);
        }
        assert_eq!(r.sorted(), vec![3, 5, 9]);
        assert_eq!(r.len(), 3);
        assert!(!r.is_empty());
        assert!(Reservoir::new(4).is_empty());
    }

    #[test]
    fn reservoir_zero_capacity_clamps_to_one() {
        let mut r = Reservoir::new(0);
        r.push(1);
        r.push(2);
        assert_eq!(r.sorted(), vec![2]);
    }
}
