//! Plain-text table formatter for bench/experiment output.
//!
//! Prints the same rows the paper's tables report, aligned for humans and
//! trivially machine-parseable (` | ` separators, one row per line).

/// Column-aligned table builder.
#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join(" | ")
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 3 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }
}

/// Format a float in engineering style matching the paper (e.g. `4.32e-5`).
pub fn sci(v: f64) -> String {
    format!("{v:.2e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let mut t = Table::new(&["a", "long-header"]);
        t.row(&["xxxx".into(), "1".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("a    | long-header"));
        assert!(lines[2].starts_with("xxxx | 1"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn sci_format() {
        assert_eq!(sci(4.32e-5), "4.32e-5");
        assert_eq!(sci(2.77e-4), "2.77e-4");
    }
}
