//! Three-region implementation (Zamanlooy & Mirhassani [3]): exploit the
//! odd symmetry and split the positive domain into
//!
//! * **pass region** `x < a`: `tanh x ≈ x` (pure wiring / shift),
//! * **processing region** `a <= x < b`: a small LUT ("bit-level
//!   mapping" — combinational logic synthesized from the truth table),
//! * **saturation region** `x >= b`: constant `1 - lsb`.

use crate::analysis::{Cost, TanhImpl};
use crate::fixed::{QFormat, Round};

/// Three-region tanh with a `2^proc_bits`-entry processing-region map.
pub struct Zamanlooy {
    fi: QFormat,
    fo: QFormat,
    /// Pass-region upper bound (input word).
    pass_end: i64,
    /// Saturation-region lower bound (input word).
    sat_start: i64,
    proc: Vec<i64>,
    proc_shift: u32,
}

impl Zamanlooy {
    /// `proc_bits`: log2 of the processing-region table size.
    pub fn new(fi: QFormat, fo: QFormat, proc_bits: u32) -> Self {
        // Region boundaries from [3]: pass while |tanh x - x| < lsb/2;
        // saturate when 1 - tanh x < lsb/2.
        let lsb = fo.lsb();
        // tanh x ~ x - x^3/3: |err| = x^3/3 < lsb/2 -> a = (1.5 lsb)^(1/3)
        let a = (1.5 * lsb).cbrt();
        let b = (2.0 / lsb).ln() / 2.0 + 0.25; // from 1 - tanh ~ 2e^-2x
        let pass_end = fi.quantize(a, Round::Floor).max(1);
        let sat_start = fi.quantize(b, Round::Floor);
        let span = (sat_start - pass_end).max(1) as u64;
        let entries = 1usize << proc_bits;
        let proc_shift = (span.next_power_of_two() / entries as u64)
            .max(1)
            .trailing_zeros();
        let proc = (0..entries as i64)
            .map(|k| {
                let centre = pass_end + (k << proc_shift) + (1i64 << proc_shift) / 2;
                fo.quantize(fi.dequantize(centre).tanh(), Round::Nearest)
            })
            .collect();
        Zamanlooy { fi, fo, pass_end, sat_start, proc, proc_shift }
    }
}

impl TanhImpl for Zamanlooy {
    fn eval_word(&self, x: i64) -> i64 {
        let neg = x < 0;
        let n = x.unsigned_abs() as i64;
        let t = if n < self.pass_end {
            // Pass region: output = input (rescaled by wiring).
            let shift = self.fo.frac_bits as i32 - self.fi.frac_bits as i32;
            if shift >= 0 {
                n << shift
            } else {
                n >> -shift
            }
        } else if n >= self.sat_start {
            self.fo.max_word()
        } else {
            let idx = (((n - self.pass_end) >> self.proc_shift) as usize)
                .min(self.proc.len() - 1);
            self.proc[idx]
        };
        let t = t.min(self.fo.max_word());
        if neg {
            -t
        } else {
            t
        }
    }

    fn in_format(&self) -> QFormat {
        self.fi
    }

    fn out_format(&self) -> QFormat {
        self.fo
    }

    fn name(&self) -> String {
        format!("zamanlooy[pass<{}, sat>={}, {} proc]",
                self.pass_end, self.sat_start, self.proc.len())
    }

    fn cost(&self) -> Cost {
        Cost {
            lut_bits: self.proc.len() as u64 * self.fo.width() as u64,
            multipliers: 0,
            adders: 1,
            comparators: 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{exhaustive_error, region_error};
    use crate::baselines::fmt16;

    #[test]
    fn regions_ordered() {
        let (fi, fo) = fmt16();
        let z = Zamanlooy::new(fi, fo, 7);
        assert!(0 < z.pass_end && z.pass_end < z.sat_start);
        assert!(z.sat_start < 1 << 15);
    }

    #[test]
    fn pass_region_is_identity() {
        let (fi, fo) = fmt16();
        let z = Zamanlooy::new(fi, fo, 7);
        for n in 0..z.pass_end {
            assert_eq!(z.eval_word(n), n << 3); // 12 -> 15 frac bits
        }
    }

    #[test]
    fn saturation_is_constant() {
        let (fi, fo) = fmt16();
        let z = Zamanlooy::new(fi, fo, 7);
        assert_eq!(z.eval_word(z.sat_start), fo.max_word());
        assert_eq!(z.eval_word(32767), fo.max_word());
    }

    #[test]
    fn overall_error_reasonable() {
        let (fi, fo) = fmt16();
        let z = Zamanlooy::new(fi, fo, 7);
        let e = exhaustive_error(&z);
        assert!(e.max_abs < 0.04, "{}", e.max_abs);
        // Error concentrates in the processing region by construction.
        let rep = region_error(&z);
        assert!(rep.processing.max_abs >= rep.saturation.max_abs);
    }
}
