//! DCT interpolation filter (Abdelsalam et al. [6]): between uniformly
//! spaced samples of tanh, interpolate with an N-tap filter whose
//! coefficients derive from the DCT basis (the DCTIF of HEVC motion
//! interpolation). Achieves the highest accuracy of the published
//! methods, at the cost of a large coefficient memory — the trade-off
//! the paper's §II and §V call out.
//!
//! For each fractional phase `p` (sub-sample position), the filter
//! coefficients `w_k(p)` are precomputed; evaluation is
//! `y = Σ_k w_k(p) · tanh(x_i + k·step)` — `taps` multipliers plus an
//! adder tree, with coefficients stored per phase.

use crate::analysis::{Cost, TanhImpl};
use crate::fixed::{QFormat, Round};

/// DCT-based interpolation filter over uniform tanh samples.
pub struct Dctif {
    fi: QFormat,
    fo: QFormat,
    taps: usize,
    phases: usize,
    samples: Vec<i64>,
    /// coeff[phase][tap], at `coeff_frac` fractional bits.
    coeff: Vec<Vec<i64>>,
    coeff_frac: u32,
    step_shift: u32,
}

/// Ideal DCT-II interpolation weights for fractional offset `alpha` in
/// [0,1) with `taps` symmetric taps.
fn dct_weights(taps: usize, alpha: f64) -> Vec<f64> {
    // Interpolate f(alpha) from samples at integer offsets
    // j - taps/2 + 1 .. using the DCT-II basis over the tap window.
    let n = taps as f64;
    let centre = taps as f64 / 2.0 - 1.0 + alpha;
    (0..taps)
        .map(|j| {
            // w_j = (1/N)(1 + 2 Σ_k cos(πk(2j+1)/2N) cos(πk(2c+1)/2N))
            let mut w = 1.0 / n;
            for k in 1..taps {
                let kk = k as f64;
                w += 2.0 / n
                    * ((std::f64::consts::PI * kk * (2.0 * j as f64 + 1.0))
                        / (2.0 * n))
                        .cos()
                    * ((std::f64::consts::PI * kk * (2.0 * centre + 1.0))
                        / (2.0 * n))
                        .cos();
            }
            w
        })
        .collect()
}

impl Dctif {
    /// `taps`: filter length (4 in [6]); `samples_pow2`: number of tanh
    /// samples over the positive domain (power of two).
    pub fn new(fi: QFormat, fo: QFormat, taps: usize, samples_pow2: usize) -> Self {
        assert!(samples_pow2.is_power_of_two() && taps >= 2);
        let half = 1i64 << (fi.width() - 1);
        let step_shift = (half as u64 / samples_pow2 as u64).trailing_zeros();
        let step = 1i64 << step_shift;
        // Extra guard samples at both ends for the filter window.
        let guard = taps as i64;
        let samples: Vec<i64> = (-guard..samples_pow2 as i64 + guard)
            .map(|k| fo.quantize(fi.dequantize(k * step).tanh(), Round::Nearest))
            .collect();
        // Phase resolution: 128 fractional phases keeps the phase
        // quantization below the filter's own error (this is exactly the
        // "huge memory for storing the coefficients" cost of [6]).
        let phases = 128usize;
        let coeff_frac = 14u32;
        let coeff = (0..phases)
            .map(|p| {
                dct_weights(taps, p as f64 / phases as f64)
                    .into_iter()
                    .map(|w| (w * (1i64 << coeff_frac) as f64).round() as i64)
                    .collect()
            })
            .collect();
        Dctif { fi, fo, taps, phases, samples, coeff, coeff_frac, step_shift }
    }

    pub fn coefficient_bits(&self) -> u64 {
        (self.phases * self.taps) as u64 * (self.coeff_frac as u64 + 2)
            + self.samples.len() as u64 * self.fo.width() as u64
    }
}

impl TanhImpl for Dctif {
    fn eval_word(&self, x: i64) -> i64 {
        let neg = x < 0;
        let n = x.unsigned_abs() as i64;
        let guard = self.taps as i64;
        let idx = n >> self.step_shift;
        let frac = n & ((1i64 << self.step_shift) - 1);
        let phase = ((frac * self.phases as i64) >> self.step_shift) as usize;
        let w = &self.coeff[phase];
        // Window starts at idx - taps/2 + 1.
        let base = idx - self.taps as i64 / 2 + 1 + guard;
        let mut acc = 0i64;
        for (k, &wk) in w.iter().enumerate() {
            let s = self
                .samples
                .get((base + k as i64) as usize)
                .copied()
                .unwrap_or(self.fo.max_word());
            acc += wk * s;
        }
        let t = ((acc + (1i64 << (self.coeff_frac - 1))) >> self.coeff_frac)
            .clamp(0, self.fo.max_word());
        if neg {
            -t
        } else {
            t
        }
    }

    /// Hoisted batch loop: window offset, phase scale and rounding
    /// constants are loop-invariant; only the 4-tap gather + dot
    /// product stays per word.
    fn eval_batch_words(&self, xs: &[i64], out: &mut [i64]) {
        assert_eq!(xs.len(), out.len());
        let shift = self.step_shift;
        let mask = (1i64 << shift) - 1;
        let phases = self.phases as i64;
        let round = 1i64 << (self.coeff_frac - 1);
        let max = self.fo.max_word();
        // Window starts at idx - taps/2 + 1, plus the guard offset.
        let off = self.taps as i64 + 1 - self.taps as i64 / 2;
        for (o, &x) in out.iter_mut().zip(xs) {
            let neg = x < 0;
            let n = x.unsigned_abs() as i64;
            let idx = n >> shift;
            let phase = (((n & mask) * phases) >> shift) as usize;
            let w = &self.coeff[phase];
            let base = idx + off;
            let mut acc = 0i64;
            for (k, &wk) in w.iter().enumerate() {
                let s = self
                    .samples
                    .get((base + k as i64) as usize)
                    .copied()
                    .unwrap_or(max);
                acc += wk * s;
            }
            let t = ((acc + round) >> self.coeff_frac).clamp(0, max);
            *o = if neg { -t } else { t };
        }
    }

    fn in_format(&self) -> QFormat {
        self.fi
    }

    fn out_format(&self) -> QFormat {
        self.fo
    }

    fn name(&self) -> String {
        format!("DCTIF[{} taps, {} samples]", self.taps,
                self.samples.len() - 2 * self.taps)
    }

    fn cost(&self) -> Cost {
        Cost {
            lut_bits: self.coefficient_bits(),
            multipliers: self.taps as u32,
            adders: self.taps as u32,
            comparators: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::exhaustive_error;
    use crate::baselines::fmt16;
    use crate::baselines::pwl::Pwl;

    #[test]
    fn weights_sum_to_one() {
        for alpha in [0.0, 0.25, 0.5, 0.75] {
            let w = dct_weights(4, alpha);
            let s: f64 = w.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "alpha={alpha}: sum {s}");
        }
    }

    #[test]
    fn integer_phase_reproduces_sample() {
        let w = dct_weights(4, 0.0);
        // At alpha=0 the filter should (nearly) select the centre sample.
        assert!(w[1] > 0.9, "{w:?}");
    }

    #[test]
    fn beats_pwl_at_same_sample_count() {
        // [6]'s claim: higher accuracy than interpolation baselines.
        let (fi, fo) = fmt16();
        let d = Dctif::new(fi, fo, 4, 64);
        let p = Pwl::new(fi, fo, 64);
        let ed = exhaustive_error(&d).max_abs;
        let ep = exhaustive_error(&p).max_abs;
        assert!(ed < ep, "dctif {ed} vs pwl {ep}");
    }

    #[test]
    fn large_memory_cost() {
        // ... but it pays in coefficient/sample storage (paper §V).
        let (fi, fo) = fmt16();
        let d = Dctif::new(fi, fo, 4, 64);
        let p = Pwl::new(fi, fo, 64);
        assert!(d.cost().lut_bits > 2 * p.cost().lut_bits);
    }

    #[test]
    fn odd() {
        let (fi, fo) = fmt16();
        let d = Dctif::new(fi, fo, 4, 64);
        for x in [3i64, 777, 10000, 32767] {
            assert_eq!(d.eval_word(x), -d.eval_word(-x));
        }
    }
}
