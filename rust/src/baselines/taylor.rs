//! Truncated Taylor series (Adnan et al. [5]):
//!
//! `tanh x = x - x³/3 + 2x⁵/15 - 17x⁷/315 + ...`
//!
//! Accurate near 0, poor near the knee — the paper's §II notes that
//! adding the 4th term buys 10x where the error was already small but
//! only 2x where it was large. Evaluated in fixed point with Horner's
//! scheme on x²; beyond the convergence radius the output is clamped to
//! the saturation value.

use crate::analysis::{Cost, TanhImpl};
use crate::fixed::{round_mul, QFormat, Round};

/// Taylor-series tanh with `terms` ∈ {2, 3, 4} terms.
pub struct Taylor {
    fi: QFormat,
    fo: QFormat,
    terms: u32,
    /// Working fraction bits for the polynomial evaluation.
    work_frac: u32,
    /// Coefficients 1, -1/3, 2/15, -17/315 at work_frac bits.
    coeffs: Vec<i64>,
    /// |x| beyond which the series is abandoned for saturation.
    sat_word: i64,
}

impl Taylor {
    pub fn new(fi: QFormat, fo: QFormat, terms: u32) -> Self {
        assert!((2..=4).contains(&terms));
        let work_frac = (fo.frac_bits + 4).min(28);
        let all = [1.0, -1.0 / 3.0, 2.0 / 15.0, -17.0 / 315.0];
        let coeffs = all[..terms as usize]
            .iter()
            .map(|c| (c * (1i64 << work_frac) as f64).round() as i64)
            .collect();
        // The truncated series stays within ~1.5% of tanh up to roughly
        // |x| ~ 1.0 (3 terms) / 1.15 (4 terms); past that we clamp to a
        // stored boundary-matched linear+saturation tail.
        let sat_x = match terms {
            2 => 0.65,
            3 => 0.90,
            _ => 1.05,
        };
        let sat_word = fi.quantize(sat_x, Round::Nearest);
        Taylor { fi, fo, terms, work_frac, coeffs, sat_word }
    }
}

impl TanhImpl for Taylor {
    fn eval_word(&self, x: i64) -> i64 {
        let neg = x < 0;
        let n = x.unsigned_abs() as i64;
        let wf = self.work_frac;
        // Promote to working precision.
        let xw = n << (wf - self.fi.frac_bits);
        let t = if n <= self.sat_word {
            let x2 = round_mul(xw, xw, wf);
            // Horner on x²: (((c3 x² + c2) x² + c1) x² + c0) · x
            let mut acc = *self.coeffs.last().unwrap();
            for &c in self.coeffs.iter().rev().skip(1) {
                acc = c + round_mul(acc, x2, wf);
            }
            let y = round_mul(acc, xw, wf);
            (y + (1i64 << (wf - self.fo.frac_bits - 1)))
                >> (wf - self.fo.frac_bits)
        } else {
            // Saturation tail: linear blend from series value at the
            // boundary to 1.0 (hardware: one stored slope).
            let x0 = self.fi.dequantize(self.sat_word);
            let y0 = x0.tanh();
            let slope = 1.0 - y0 * y0; // tanh'(x0)
            let xr = self.fi.dequantize(n);
            let y = (y0 + slope * (xr - x0) * 0.5).min(1.0 - self.fo.lsb());
            self.fo.quantize(y, Round::Nearest)
        };
        let t = t.clamp(0, self.fo.max_word());
        if neg {
            -t
        } else {
            t
        }
    }

    fn in_format(&self) -> QFormat {
        self.fi
    }

    fn out_format(&self) -> QFormat {
        self.fo
    }

    fn name(&self) -> String {
        format!("Taylor[{} terms]", self.terms)
    }

    fn cost(&self) -> Cost {
        Cost {
            lut_bits: (self.terms as u64 + 2) * (self.work_frac as u64 + 2),
            // x², Horner multiplies, final x multiply.
            multipliers: self.terms,
            adders: self.terms - 1,
            comparators: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::sweep_error;
    use crate::baselines::fmt16;

    fn near_zero_words() -> Vec<i64> {
        (-1500..1500).collect() // |x| < 0.37
    }

    #[test]
    fn very_accurate_near_zero() {
        let (fi, fo) = fmt16();
        let t3 = Taylor::new(fi, fo, 3);
        let e = sweep_error(&t3, &near_zero_words());
        assert!(e.max_abs < 2e-4, "{}", e.max_abs);
    }

    #[test]
    fn fourth_term_helps_most_where_error_small() {
        // The paper's observation: going 3 -> 4 terms improves the
        // near-zero error far more than the knee error.
        let (fi, fo) = fmt16();
        let t3 = Taylor::new(fi, fo, 3);
        let t4 = Taylor::new(fi, fo, 4);
        let near: Vec<i64> = (2400..3300).collect(); // x in (0.58, 0.81)
        let e3n = sweep_error(&t3, &near).max_abs;
        let e4n = sweep_error(&t4, &near).max_abs;
        assert!(e4n < e3n, "4-term should help near zero: {e4n} vs {e3n}");
    }

    #[test]
    fn knee_error_dominates() {
        let (fi, fo) = fmt16();
        let t3 = Taylor::new(fi, fo, 3);
        let knee: Vec<i64> = (3200..8000).collect();
        let e_near = sweep_error(&t3, &near_zero_words()).max_abs;
        let e_knee = sweep_error(&t3, &knee).max_abs;
        assert!(e_knee > 5.0 * e_near);
    }

    #[test]
    fn odd_function() {
        let (fi, fo) = fmt16();
        let t = Taylor::new(fi, fo, 3);
        for x in [1i64, 100, 2000, 4000, 20000] {
            assert_eq!(t.eval_word(x), -t.eval_word(-x));
        }
    }
}
