//! Uniform lookup table: the simplest published implementation — store
//! `tanh` at equally spaced points over the positive domain and return
//! the nearest entry. The paper's §II notes the accuracy/area tension:
//! the flat saturation tail wastes entries that the steep origin needs.

use crate::analysis::{Cost, TanhImpl};
use crate::fixed::{QFormat, Round};

/// Nearest-entry uniform LUT over `[0, max_input]`.
pub struct UniformLut {
    fi: QFormat,
    fo: QFormat,
    entries: Vec<i64>,
    /// Input words per LUT step (power of two).
    step_shift: u32,
}

impl UniformLut {
    /// `size` must be a power of two covering the positive input domain.
    pub fn new(fi: QFormat, fo: QFormat, size: usize) -> Self {
        assert!(size.is_power_of_two());
        let half = 1i64 << (fi.width() - 1);
        let step_shift = (half as u64 / size as u64).trailing_zeros();
        let step = 1i64 << step_shift;
        // Entry k covers [k*step, (k+1)*step); sample the interval centre
        // (halves the worst-case error vs sampling the left edge).
        let entries = (0..size as i64)
            .map(|k| {
                let centre = k * step + step / 2;
                fo.quantize(fi.dequantize(centre).tanh(), Round::Nearest)
            })
            .collect();
        UniformLut { fi, fo, entries, step_shift }
    }

    pub fn size(&self) -> usize {
        self.entries.len()
    }
}

impl TanhImpl for UniformLut {
    fn eval_word(&self, x: i64) -> i64 {
        if x == 0 {
            return 0; // keep tanh(0) = 0 exactly (oddness)
        }
        let neg = x < 0;
        let n = x.unsigned_abs() as i64;
        let idx = ((n >> self.step_shift) as usize).min(self.entries.len() - 1);
        let t = self.entries[idx];
        if neg {
            -t
        } else {
            t
        }
    }

    fn in_format(&self) -> QFormat {
        self.fi
    }

    fn out_format(&self) -> QFormat {
        self.fo
    }

    fn name(&self) -> String {
        format!("uniform-LUT[{}]", self.entries.len())
    }

    fn cost(&self) -> Cost {
        Cost {
            lut_bits: self.entries.len() as u64 * self.fo.width() as u64,
            multipliers: 0,
            adders: 0,
            comparators: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::exhaustive_error;
    use crate::baselines::{fmt16, fmt8};

    #[test]
    fn error_scales_inversely_with_size() {
        let (fi, fo) = fmt16();
        let e64 = exhaustive_error(&UniformLut::new(fi, fo, 64)).max_abs;
        let e512 = exhaustive_error(&UniformLut::new(fi, fo, 512)).max_abs;
        // 8x entries ~> ~8x lower max error (linear in step size).
        assert!(e512 < e64 / 4.0, "e64={e64} e512={e512}");
    }

    #[test]
    fn centre_sampling_beats_half_step() {
        let (fi, fo) = fmt16();
        let lut = UniformLut::new(fi, fo, 256);
        let e = exhaustive_error(&lut);
        // step = 8/256 = 1/32 in x; max slope 1 -> err <= step/2 + lsb.
        assert!(e.max_abs <= 1.0 / 64.0 + 2.0 * fo.lsb(), "{}", e.max_abs);
    }

    #[test]
    fn odd_and_saturating() {
        let (fi, fo) = fmt8();
        let lut = UniformLut::new(fi, fo, 64);
        assert_eq!(lut.eval_word(-100), -lut.eval_word(100));
        assert!(fo.dequantize(lut.eval_word(255)) > 0.98);
    }
}
