//! Range-addressable LUT (Leboeuf et al. [1]): the step size adapts to
//! the local variability of tanh — fine steps near the origin where the
//! slope is ~1, exponentially coarser steps toward saturation where the
//! function flattens. The address is formed from the magnitude's leading
//! one position (a priority encoder) plus the next few bits, so lookup
//! stays a single access with no multiplier.

use crate::analysis::{Cost, TanhImpl};
use crate::fixed::{QFormat, Round};

/// Range-addressable LUT: one bank of `2^sub_bits` entries per leading-one
/// position ("range"), sampled at the bank's local step size.
pub struct RangeLut {
    fi: QFormat,
    fo: QFormat,
    /// banks[range][sub] = tanh sampled at the sub-interval centre.
    banks: Vec<Vec<i64>>,
}

impl RangeLut {
    pub fn new(fi: QFormat, fo: QFormat, sub_bits: u32) -> Self {
        let mag_bits = fi.width() - 1;
        // Range r covers [2^r, 2^(r+1)) input words (range 0 covers [0, 2)).
        let banks = (0..mag_bits)
            .map(|r| {
                let lo = if r == 0 { 0 } else { 1i64 << r };
                let span = if r == 0 { 2 } else { 1i64 << r };
                let entries = 1i64 << sub_bits.min(r.max(1));
                (0..entries)
                    .map(|s| {
                        let centre = lo + span * (2 * s + 1) / (2 * entries);
                        fo.quantize(fi.dequantize(centre).tanh(), Round::Nearest)
                    })
                    .collect()
            })
            .collect();
        RangeLut { fi, fo, banks }
    }

    pub fn total_entries(&self) -> usize {
        self.banks.iter().map(Vec::len).sum()
    }
}

impl TanhImpl for RangeLut {
    fn eval_word(&self, x: i64) -> i64 {
        let neg = x < 0;
        let n = x.unsigned_abs() as i64;
        let t = if n == 0 {
            0
        } else {
            let r = (63 - n.leading_zeros()) as usize; // leading-one position
            let r = r.min(self.banks.len() - 1);
            let bank = &self.banks[r];
            let span_shift = if r == 0 { 1 } else { r as u32 };
            let lo = if r == 0 { 0 } else { 1i64 << r };
            let idx = (((n - lo) << bank.len().trailing_zeros()) >> span_shift)
                as usize;
            bank[idx.min(bank.len() - 1)]
        };
        if neg {
            -t
        } else {
            t
        }
    }

    /// Hoisted batch loop (drops the per-word dyn dispatch; the range
    /// decode itself is already a handful of scalar ops).
    fn eval_batch_words(&self, xs: &[i64], out: &mut [i64]) {
        assert_eq!(xs.len(), out.len());
        let top = self.banks.len() - 1;
        for (o, &x) in out.iter_mut().zip(xs) {
            let neg = x < 0;
            let n = x.unsigned_abs() as i64;
            let t = if n == 0 {
                0
            } else {
                let r = ((63 - n.leading_zeros()) as usize).min(top);
                let bank = &self.banks[r];
                let span_shift = if r == 0 { 1 } else { r as u32 };
                let lo = if r == 0 { 0 } else { 1i64 << r };
                let idx = (((n - lo) << bank.len().trailing_zeros())
                    >> span_shift) as usize;
                bank[idx.min(bank.len() - 1)]
            };
            *o = if neg { -t } else { t };
        }
    }

    fn in_format(&self) -> QFormat {
        self.fi
    }

    fn out_format(&self) -> QFormat {
        self.fo
    }

    fn name(&self) -> String {
        format!("range-LUT[{} entries]", self.total_entries())
    }

    fn cost(&self) -> Cost {
        Cost {
            lut_bits: self.total_entries() as u64 * self.fo.width() as u64,
            multipliers: 0,
            adders: 1,
            comparators: self.banks.len() as u32, // priority encoder
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::exhaustive_error;
    use crate::baselines::fmt16;
    use crate::baselines::lut::UniformLut;

    #[test]
    fn beats_uniform_lut_at_equal_storage() {
        // The RALUT's raison d'être: better accuracy per entry.
        let (fi, fo) = fmt16();
        let ra = RangeLut::new(fi, fo, 6);
        let entries = ra.total_entries();
        let uni_size = entries.next_power_of_two();
        let uni = UniformLut::new(fi, fo, uni_size);
        let e_ra = exhaustive_error(&ra).max_abs;
        let e_uni = exhaustive_error(&uni).max_abs;
        assert!(
            e_ra < e_uni,
            "RALUT[{entries}] {e_ra} should beat uniform[{uni_size}] {e_uni}"
        );
    }

    #[test]
    fn fine_near_origin_coarse_at_tail() {
        let (fi, fo) = fmt16();
        let ra = RangeLut::new(fi, fo, 6);
        // Error in [0, 0.5) must be far smaller than a coarse uniform LUT.
        let near: Vec<i64> = (0..2048).collect();
        let e = crate::analysis::sweep_error(&ra, &near);
        assert!(e.max_abs < 4e-3, "{}", e.max_abs);
    }

    #[test]
    fn zero_and_odd() {
        let (fi, fo) = fmt16();
        let ra = RangeLut::new(fi, fo, 6);
        assert_eq!(ra.eval_word(0), 0);
        for x in [5i64, 333, 9000, 32000] {
            assert_eq!(ra.eval_word(x), -ra.eval_word(-x));
        }
    }
}
