//! Hyperbolic CORDIC: rotate through `artanh(2^-i)` micro-angles to
//! accumulate `sinh x` and `cosh x`, then divide. Classic iterations
//! with the mandatory repeats at i = 4, 13, 40 for convergence. High
//! accuracy but one full adder-stage of latency *per iteration* — the
//! "higher latency" family the paper's §V contrasts against.

use crate::analysis::{Cost, TanhImpl};
use crate::fixed::QFormat;

/// Hyperbolic-mode CORDIC tanh.
pub struct Cordic {
    fi: QFormat,
    fo: QFormat,
    iters: u32,
    work_frac: u32,
    /// artanh(2^-i) angles at work_frac bits, with repeats.
    angles: Vec<(u32, i64)>,
    /// 1/K_h gain correction at work_frac bits.
    inv_gain: i64,
}

impl Cordic {
    pub fn new(fi: QFormat, fo: QFormat, iters: u32) -> Self {
        let work_frac = 28u32.min(fo.frac_bits + 13);
        let one = 1i64 << work_frac;
        let mut angles = Vec::new();
        let mut gain = 1.0f64;
        let mut i = 1u32;
        let mut count = 0;
        let mut next_repeat = 4u32;
        while count < iters {
            let a = ((2f64).powi(-(i as i32))).atanh();
            angles.push((i, (a * one as f64).round() as i64));
            gain *= (1.0 - (2f64).powi(-2 * (i as i32))).sqrt();
            count += 1;
            if i == next_repeat && count < iters {
                // repeat this i once for convergence
                angles.push((i, (a * one as f64).round() as i64));
                gain *= (1.0 - (2f64).powi(-2 * (i as i32))).sqrt();
                count += 1;
                next_repeat = next_repeat * 3 + 1; // 4, 13, 40...
            }
            i += 1;
        }
        Cordic {
            fi,
            fo,
            iters,
            work_frac,
            angles,
            inv_gain: ((1.0 / gain) * one as f64).round() as i64,
        }
    }

    /// Max convergence angle Σ artanh(2^-i) (≈ 1.118 for standard set).
    pub fn max_angle(&self) -> f64 {
        self.angles.iter().map(|&(_, a)| a as f64).sum::<f64>()
            / (1i64 << self.work_frac) as f64
    }
}

impl TanhImpl for Cordic {
    fn eval_word(&self, x: i64) -> i64 {
        if x == 0 {
            return 0; // zero-detect keeps exact oddness
        }
        let neg = x < 0;
        let n = x.unsigned_abs() as i64;
        let wf = self.work_frac;
        let one = 1i64 << wf;

        // Range reduction: tanh(x) for x > max_angle via
        // tanh(a + k·ln2) identity is complex; hardware typically pairs
        // CORDIC with a saturation region — convergence limit ~1.118, and
        // for x > 1.118 we use tanh(x) = (tanh(x/2)·2)/(1+tanh²(x/2))
        // applied recursively (halving shifts only).
        let xw = n << (wf - self.fi.frac_bits);
        let t = self.tanh_core(xw);
        let t_out = ((t + (1i64 << (wf - self.fo.frac_bits - 1)))
            >> (wf - self.fo.frac_bits))
            .clamp(0, self.fo.max_word());
        let _ = one;
        if neg {
            -t_out
        } else {
            t_out
        }
    }

    fn in_format(&self) -> QFormat {
        self.fi
    }

    fn out_format(&self) -> QFormat {
        self.fo
    }

    fn name(&self) -> String {
        format!("CORDIC[{} iters]", self.iters)
    }

    fn cost(&self) -> Cost {
        Cost {
            lut_bits: self.angles.len() as u64 * (self.work_frac as u64 + 2),
            multipliers: 1, // final sinh/cosh divide (NR) amortized
            adders: 3 * self.angles.len() as u32, // x, y, z updates / iter
            comparators: self.angles.len() as u32,
        }
    }
}

impl Cordic {
    /// tanh of a u·.work_frac word via doubling-reduction + CORDIC core.
    fn tanh_core(&self, xw: i64) -> i64 {
        let wf = self.work_frac;
        let one = 1i64 << wf;
        let limit = ((self.max_angle() - 0.05) * one as f64) as i64;
        if xw > limit {
            // tanh(2a) = 2 tanh a / (1 + tanh² a)
            let th = self.tanh_core(xw >> 1);
            let th2 = (th * th + (one >> 1)) >> wf;
            let den = one + th2; // in [1, 2)
            // Divide 2·th by den with a 3-stage NR on den/2 ∈ [0.5, 1).
            let d = den >> 1;
            let mut r = (11i64 << (wf - 2)) - (d << 1);
            for _ in 0..3 {
                let t0 = (d * r + (one >> 1)) >> wf;
                r = (r * ((2 * one) - t0) + (one >> 1)) >> wf;
            }
            // 2·th / den = th · r / 2^wf   (since den = 2d)
            return (th * r + (one >> 1)) >> wf;
        }
        // Rotation mode: drive z -> 0, accumulating (cosh, sinh).
        let mut cx = self.inv_gain; // cosh accumulator (pre-scaled by 1/K)
        let mut sy = 0i64; // sinh accumulator
        let mut z = xw;
        for &(i, a) in &self.angles {
            let (dx, dy) = (sy >> i, cx >> i);
            if z >= 0 {
                cx += dx;
                sy += dy;
                z -= a;
            } else {
                cx -= dx;
                sy -= dy;
                z += a;
            }
        }
        // tanh = sinh/cosh, cosh ∈ [1, ~1.7): NR on cosh/2.
        let d = cx >> 1;
        let mut r = (11i64 << (wf - 2)) - (d << 1);
        for _ in 0..3 {
            let t0 = (d * r + (one >> 1)) >> wf;
            r = (r * ((2 * one) - t0) + (one >> 1)) >> wf;
        }
        ((sy >> 1) * r + (one >> 1)) >> wf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::exhaustive_error;
    use crate::baselines::fmt16;

    #[test]
    fn angles_include_repeat_at_4() {
        let (fi, fo) = fmt16();
        let c = Cordic::new(fi, fo, 15);
        let count4 = c.angles.iter().filter(|&&(i, _)| i == 4).count();
        assert_eq!(count4, 2, "iteration 4 must repeat");
        let count13 = c.angles.iter().filter(|&&(i, _)| i == 13).count();
        assert_eq!(count13, 2, "iteration 13 must repeat");
    }

    #[test]
    fn convergence_range() {
        let (fi, fo) = fmt16();
        let c = Cordic::new(fi, fo, 15);
        assert!(c.max_angle() > 1.0 && c.max_angle() < 1.2);
    }

    #[test]
    fn accurate_in_core_range(){
        let (fi, fo) = fmt16();
        let c = Cordic::new(fi, fo, 15);
        let xs: Vec<i64> = (-4000..4000).collect(); // |x| < 0.98
        let e = crate::analysis::sweep_error(&c, &xs);
        assert!(e.max_abs < 3e-4, "{}", e.max_abs);
    }

    #[test]
    fn doubling_extension_covers_full_domain() {
        let (fi, fo) = fmt16();
        let c = Cordic::new(fi, fo, 15);
        let e = exhaustive_error(&c);
        assert!(e.max_abs < 1e-3, "{}", e.max_abs);
    }

    #[test]
    fn more_iterations_more_accurate() {
        let (fi, fo) = fmt16();
        let xs: Vec<i64> = (-4000..4000).step_by(7).collect();
        let e8 = crate::analysis::sweep_error(&Cordic::new(fi, fo, 8), &xs).max_abs;
        let e16 = crate::analysis::sweep_error(&Cordic::new(fi, fo, 16), &xs).max_abs;
        assert!(e16 < e8, "e16 {e16} vs e8 {e8}");
    }
}
