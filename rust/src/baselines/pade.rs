//! Padé approximant (Hajduk [7]): rational approximation
//!
//! * order 2: `tanh x ≈ x(15 + x²) / (15 + 6x²)`      ([3/2] Padé)
//! * order 3: `tanh x ≈ x(105 + 10x²) / (105 + 45x² + x⁴)` ([5/4] Padé)
//!
//! evaluated in fixed point, with the same Newton-Raphson reciprocal the
//! velocity-factor unit uses for its divider. The rational form is very
//! accurate near 0 and degrades past |x| ≈ 2–3, where it hands over to
//! saturation. The paper's §V: "higher accuracy implementations, such as
//! using Padé approximants ... have higher latencies" — the divider sits
//! on the critical path here with *wide* operands, unlike the VF method
//! where it only sees the final (0,1) fraction.

use crate::analysis::{Cost, TanhImpl};
use crate::fixed::{round_mul, QFormat, Round};

/// Fixed-point Padé tanh with an NR divider (3 stages).
pub struct Pade {
    fi: QFormat,
    fo: QFormat,
    order: u32,
    work_frac: u32,
    sat_word: i64,
}

impl Pade {
    /// `order`: 2 -> [3/2], 3 -> [5/4].
    pub fn new(fi: QFormat, fo: QFormat, order: u32) -> Self {
        assert!((2..=3).contains(&order));
        // Saturation handover where the approximant's error crosses ~lsb
        // of a 16-bit output: |x| ~ 2.1 for [3/2], 3.3 for [5/4].
        let sat_x = if order == 2 { 2.1 } else { 3.3 };
        Pade {
            fi,
            fo,
            order,
            work_frac: 20,
            sat_word: fi.quantize(sat_x, Round::Nearest),
        }
    }
}

impl TanhImpl for Pade {
    fn eval_word(&self, x: i64) -> i64 {
        let neg = x < 0;
        let n = x.unsigned_abs() as i64;
        let wf = self.work_frac;
        let one = 1i64 << wf;

        let t = if n >= self.sat_word {
            self.fo.max_word()
        } else {
            let xw = n << (wf - self.fi.frac_bits);
            let x2 = round_mul(xw, xw, wf);
            // Numerator / denominator scaled by 1/105 (or 1/15) so both
            // stay in a narrow fixed-point range.
            let (num, den) = if self.order == 2 {
                // x(15 + x²)/15 over (15 + 6x²)/15
                let num = round_mul(xw, one + x2 / 15, wf);
                let den = one + (2 * x2) / 5;
                (num, den)
            } else {
                let x4 = round_mul(x2, x2, wf);
                let num = round_mul(xw, one + (2 * x2) / 21, wf);
                let den = one + (3 * x2) / 7 + x4 / 105;
                (num, den)
            };
            // NR reciprocal of den ∈ [1, ~5): normalize to [0.5, 1).
            let shift = 64 - (den as u64).leading_zeros() - 1; // msb position
            let dn = (den << wf) >> (shift + 1); // u0.wf in [0.5, 1)
            let mut r = (11i64 << (wf - 2)) - (dn << 1); // 2.75 - 2d
            for _ in 0..3 {
                let t0 = round_mul(dn, r, wf);
                r = round_mul(r, (2i64 << wf) - t0, wf);
            }
            // num/den = num * r / 2^(shift - wf + 1)... : den = dn * 2^(shift-wf+1)
            let q = round_mul(num, r, wf); // num / dn
            let down = shift as i32 - wf as i32 + 1;
            let q = if down >= 0 { q >> down } else { q << -down };
            ((q + (1i64 << (wf - self.fo.frac_bits - 1)))
                >> (wf - self.fo.frac_bits))
                .clamp(0, self.fo.max_word())
        };
        if neg {
            -t
        } else {
            t
        }
    }

    fn in_format(&self) -> QFormat {
        self.fi
    }

    fn out_format(&self) -> QFormat {
        self.fo
    }

    fn name(&self) -> String {
        format!("Pade[{}]", if self.order == 2 { "3/2" } else { "5/4" })
    }

    fn cost(&self) -> Cost {
        Cost {
            lut_bits: 64,
            // x², (x⁴), num, den muls + 2/NR stage + quotient.
            multipliers: 2 + self.order + 6 + 1,
            adders: 4,
            comparators: 2, // saturation + normalization
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{exhaustive_error, sweep_error};
    use crate::baselines::fmt16;

    #[test]
    fn very_accurate_core_region() {
        let (fi, fo) = fmt16();
        let p = Pade::new(fi, fo, 3);
        let core: Vec<i64> = (-6000..6000).collect(); // |x| < 1.47
        let e = sweep_error(&p, &core);
        assert!(e.max_abs < 1e-3, "{}", e.max_abs);
    }

    #[test]
    fn order3_beats_order2() {
        let (fi, fo) = fmt16();
        let e2 = exhaustive_error(&Pade::new(fi, fo, 2)).max_abs;
        let e3 = exhaustive_error(&Pade::new(fi, fo, 3)).max_abs;
        assert!(e3 < e2, "order3 {e3} vs order2 {e2}");
    }

    #[test]
    fn odd() {
        let (fi, fo) = fmt16();
        let p = Pade::new(fi, fo, 3);
        for x in [1i64, 99, 5000, 20000] {
            assert_eq!(p.eval_word(x), -p.eval_word(-x));
        }
    }

    #[test]
    fn overall_error_bounded() {
        let (fi, fo) = fmt16();
        let e = exhaustive_error(&Pade::new(fi, fo, 3));
        assert!(e.max_abs < 0.01, "{}", e.max_abs);
    }
}
