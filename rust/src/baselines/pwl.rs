//! Piecewise-linear interpolation (Lin & Wang [4], and the curve in the
//! paper's fig. 1): store `tanh` at uniformly spaced knots; between
//! knots, interpolate linearly with one multiplier.

use crate::analysis::{Cost, TanhImpl};
use crate::fixed::{QFormat, Round};

/// Uniform-knot PWL interpolator over the positive domain.
pub struct Pwl {
    fi: QFormat,
    fo: QFormat,
    /// Knot values tanh(k * step), k = 0..=segments.
    knots: Vec<i64>,
    /// Input words per segment (power of two).
    step_shift: u32,
}

impl Pwl {
    pub fn new(fi: QFormat, fo: QFormat, segments: usize) -> Self {
        assert!(segments.is_power_of_two());
        let half = 1i64 << (fi.width() - 1);
        let step_shift = (half as u64 / segments as u64).trailing_zeros();
        let step = 1i64 << step_shift;
        let knots = (0..=segments as i64)
            .map(|k| fo.quantize(fi.dequantize(k * step).tanh(), Round::Nearest))
            .collect();
        Pwl { fi, fo, knots, step_shift }
    }

    pub fn segments(&self) -> usize {
        self.knots.len() - 1
    }
}

impl TanhImpl for Pwl {
    fn eval_word(&self, x: i64) -> i64 {
        let neg = x < 0;
        let n = x.unsigned_abs() as i64;
        let idx = ((n >> self.step_shift) as usize).min(self.segments() - 1);
        let frac = n & ((1i64 << self.step_shift) - 1);
        let (y0, y1) = (self.knots[idx], self.knots[idx + 1]);
        // y = y0 + (y1-y0) * frac / step  (one multiplier, one shift)
        let t = y0
            + (((y1 - y0) * frac + (1i64 << (self.step_shift - 1)))
                >> self.step_shift);
        if neg {
            -t
        } else {
            t
        }
    }

    /// Hoisted batch loop: the segment geometry is loop-invariant, so
    /// lifting it (and ditching the per-word dyn dispatch) leaves a
    /// branch-light body the autovectorizer handles well.
    fn eval_batch_words(&self, xs: &[i64], out: &mut [i64]) {
        assert_eq!(xs.len(), out.len());
        let last = self.segments() - 1;
        let shift = self.step_shift;
        let mask = (1i64 << shift) - 1;
        let round = 1i64 << (shift - 1);
        let knots = &self.knots[..];
        for (o, &x) in out.iter_mut().zip(xs) {
            let neg = x < 0;
            let n = x.unsigned_abs() as i64;
            let idx = ((n >> shift) as usize).min(last);
            let frac = n & mask;
            let (y0, y1) = (knots[idx], knots[idx + 1]);
            let t = y0 + (((y1 - y0) * frac + round) >> shift);
            *o = if neg { -t } else { t };
        }
    }

    fn in_format(&self) -> QFormat {
        self.fi
    }

    fn out_format(&self) -> QFormat {
        self.fo
    }

    fn name(&self) -> String {
        format!("PWL[{}]", self.segments())
    }

    fn cost(&self) -> Cost {
        Cost {
            lut_bits: self.knots.len() as u64 * self.fo.width() as u64,
            multipliers: 1,
            adders: 2,
            comparators: 1,
        }
    }
}

/// Generate the fig. 1 series: true tanh and its PWL approximation over
/// a uniform x grid (for the `fig1_pwl` bench artifact).
pub fn fig1_series(segments: usize, points: usize) -> Vec<(f64, f64, f64)> {
    let (fi, fo) = (QFormat::new(3, 12), QFormat::new(0, 15));
    let pwl = Pwl::new(fi, fo, segments);
    (0..points)
        .map(|i| {
            let x = -4.0 + 8.0 * i as f64 / (points - 1) as f64;
            let w = fi.quantize(x, Round::Nearest);
            (x, x.tanh(), fo.dequantize(pwl.eval_word(w)))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::exhaustive_error;
    use crate::baselines::fmt16;

    #[test]
    fn interpolation_quadratic_convergence() {
        // PWL error ~ step^2 * max|f''|/8: 2x segments -> ~4x lower error.
        let (fi, fo) = fmt16();
        let e16 = exhaustive_error(&Pwl::new(fi, fo, 16)).max_abs;
        let e64 = exhaustive_error(&Pwl::new(fi, fo, 64)).max_abs;
        assert!(e64 < e16 / 6.0, "e16={e16} e64={e64}");
    }

    #[test]
    fn exact_at_knots() {
        let (fi, fo) = fmt16();
        let pwl = Pwl::new(fi, fo, 32);
        let step = 1i64 << pwl.step_shift;
        for k in 0..8 {
            let x = k * step;
            let want = fo.quantize(fi.dequantize(x).tanh(), Round::Nearest);
            assert_eq!(pwl.eval_word(x), want);
        }
    }

    #[test]
    fn fig1_series_shape() {
        let series = fig1_series(8, 101);
        assert_eq!(series.len(), 101);
        // Approximation stays within the coarse-PWL band of the true curve
        // (8 segments over [0,8): first-segment chord error of tanh peaks
        // at 0.082 near x=0.555 — the visible gap in the paper's fig. 1).
        for (x, t, p) in &series {
            assert!((t - p).abs() < 0.09, "x={x}: {t} vs {p}");
        }
        // Odd-ish symmetry of the sampled series.
        let (_, t0, p0) = series[0];
        let (_, t1, p1) = series[100];
        assert!((t0 + t1).abs() < 1e-9 && (p0 + p1).abs() < 1e-3);
    }
}
