//! Two-step approximation (Namin et al. [2]): a *coarse* stage made of
//! a limited slope-1 pass-through plus saturation (no memory at all),
//! refined by a small LUT holding the residual `tanh(x) - coarse(x)`.

use crate::analysis::{Cost, TanhImpl};
use crate::fixed::{QFormat, Round};

/// Coarse linear+saturation stage with a fine residual LUT.
pub struct TwoStep {
    fi: QFormat,
    fo: QFormat,
    /// residual[k] = tanh(centre_k) - coarse(centre_k).
    residual: Vec<i64>,
    step_shift: u32,
}

fn coarse(x: f64) -> f64 {
    // min(x, 1): the crude linear+saturation estimate of [2].
    x.min(1.0)
}

impl TwoStep {
    pub fn new(fi: QFormat, fo: QFormat, size: usize) -> Self {
        assert!(size.is_power_of_two());
        let half = 1i64 << (fi.width() - 1);
        let step_shift = (half as u64 / size as u64).trailing_zeros();
        let step = 1i64 << step_shift;
        let residual = (0..size as i64)
            .map(|k| {
                let centre = fi.dequantize(k * step + step / 2);
                fo.quantize(centre.tanh() - coarse(centre), Round::Nearest)
            })
            .collect();
        TwoStep { fi, fo, residual, step_shift }
    }
}

impl TanhImpl for TwoStep {
    fn eval_word(&self, x: i64) -> i64 {
        let neg = x < 0;
        let n = x.unsigned_abs() as i64;
        // Coarse: min(x, 1) in output format — a shift and a clamp.
        let shift = self.fo.frac_bits as i32 - self.fi.frac_bits as i32;
        let lin = if shift >= 0 { n << shift } else { n >> -shift };
        let c = lin.min(1i64 << self.fo.frac_bits);
        // Fine: residual LUT on the high bits.
        let idx = ((n >> self.step_shift) as usize).min(self.residual.len() - 1);
        let t = (c + self.residual[idx]).clamp(0, self.fo.max_word());
        if neg {
            -t
        } else {
            t
        }
    }

    fn in_format(&self) -> QFormat {
        self.fi
    }

    fn out_format(&self) -> QFormat {
        self.fo
    }

    fn name(&self) -> String {
        format!("two-step[{}]", self.residual.len())
    }

    fn cost(&self) -> Cost {
        Cost {
            lut_bits: self.residual.len() as u64 * self.fo.width() as u64,
            multipliers: 0,
            adders: 1,
            comparators: 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::exhaustive_error;
    use crate::baselines::fmt16;
    use crate::baselines::lut::UniformLut;

    #[test]
    fn beats_plain_lut_at_equal_size() {
        // Residual has far smaller dynamic range than tanh itself, so the
        // same entry count quantizes it better.
        let (fi, fo) = fmt16();
        let ts = TwoStep::new(fi, fo, 64);
        let uni = UniformLut::new(fi, fo, 64);
        let e_ts = exhaustive_error(&ts).max_abs;
        let e_uni = exhaustive_error(&uni).max_abs;
        assert!(e_ts < e_uni, "two-step {e_ts} vs uniform {e_uni}");
    }

    #[test]
    fn near_zero_is_linear_dominated() {
        let (fi, fo) = fmt16();
        let ts = TwoStep::new(fi, fo, 64);
        // In |x| < 0.2 the pass-through carries the signal; error small.
        let near: Vec<i64> = (-800..800).collect();
        let e = crate::analysis::sweep_error(&ts, &near);
        assert!(e.max_abs < 6e-3, "{}", e.max_abs);
    }

    #[test]
    fn odd() {
        let (fi, fo) = fmt16();
        let ts = TwoStep::new(fi, fo, 64);
        for x in [1i64, 50, 4096, 30000] {
            assert_eq!(ts.eval_word(x), -ts.eval_word(-x));
        }
    }
}
