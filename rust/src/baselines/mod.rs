//! Baseline tanh implementations from the paper's literature review
//! (§II), all in fixed point against the same [`crate::analysis::TanhImpl`]
//! interface so the comparison benches can sweep accuracy vs hardware
//! cost uniformly:
//!
//! | module      | reference                  | idea                                |
//! |-------------|----------------------------|-------------------------------------|
//! | [`lut`]     | classic                    | uniform nearest-entry lookup        |
//! | [`ralut`]   | Leboeuf et al. [1]         | range-addressable (variable-step) LUT |
//! | [`twostep`] | Namin et al. [2]           | coarse linear+saturation, fine LUT  |
//! | [`zamanlooy`]| Zamanlooy & Mirhassani [3]| pass / processing / saturation regions |
//! | [`pwl`]     | Lin & Wang [4]             | piecewise-linear interpolation      |
//! | [`taylor`]  | Adnan et al. [5]           | truncated Taylor series             |
//! | [`dctif`]   | Abdelsalam et al. [6]      | DCT interpolation filter            |
//! | [`pade`]    | Hajduk [7]                 | Padé rational approximant + divider |
//! | [`cordic`]  | classic                    | hyperbolic CORDIC (sinh/cosh + div) |
//!
//! All of them target the paper's canonical formats (s3.12 -> s.15 and
//! s3.5 -> s.7) but are parameterized over [`crate::fixed::QFormat`].

pub mod cordic;
pub mod dctif;
pub mod lut;
pub mod pade;
pub mod pwl;
pub mod ralut;
pub mod taylor;
pub mod twostep;
pub mod zamanlooy;

use crate::analysis::TanhImpl;
use crate::fixed::QFormat;

/// The standard 16-bit evaluation formats used across baselines.
pub fn fmt16() -> (QFormat, QFormat) {
    (QFormat::new(3, 12), QFormat::new(0, 15))
}

/// The standard 8-bit evaluation formats.
pub fn fmt8() -> (QFormat, QFormat) {
    (QFormat::new(3, 5), QFormat::new(0, 7))
}

/// Construct the full baseline suite at comparable (16-bit) operating
/// points, for the comparison bench.
pub fn suite16() -> Vec<Box<dyn TanhImpl>> {
    let (fi, fo) = fmt16();
    vec![
        Box::new(lut::UniformLut::new(fi, fo, 256)),
        Box::new(ralut::RangeLut::new(fi, fo, 6)),
        Box::new(twostep::TwoStep::new(fi, fo, 64)),
        Box::new(zamanlooy::Zamanlooy::new(fi, fo, 7)),
        Box::new(pwl::Pwl::new(fi, fo, 32)),
        Box::new(taylor::Taylor::new(fi, fo, 3)),
        Box::new(taylor::Taylor::new(fi, fo, 4)),
        Box::new(dctif::Dctif::new(fi, fo, 4, 64)),
        Box::new(pade::Pade::new(fi, fo, 3)),
        Box::new(cordic::Cordic::new(fi, fo, 15)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::sweep_error;

    #[test]
    fn suite_all_odd_and_bounded() {
        for imp in suite16() {
            for x in [0i64, 3, 700, 4096, 12000, 32767] {
                let y = imp.eval_word(x);
                let yn = imp.eval_word(-x);
                assert_eq!(y, -yn, "{} not odd at {x}", imp.name());
                assert!(y.abs() < 1 << 15, "{} out of range", imp.name());
            }
        }
    }

    #[test]
    fn suite_sane_accuracy() {
        // Every baseline must be a plausible tanh (max err < 0.06 —
        // even the crudest LUT at 256 entries).
        let xs: Vec<i64> = (-32768..32768).step_by(37).collect();
        for imp in suite16() {
            let e = sweep_error(imp.as_ref(), &xs);
            assert!(e.max_abs < 0.06, "{}: {}", imp.name(), e.max_abs);
        }
    }
}
