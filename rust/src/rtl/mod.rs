//! Cycle-accurate RTL simulation of the pipelined datapath.
//!
//! Simulates the synthesized netlist with its pipeline stage assignment
//! at clock granularity: a new input word may be accepted every clock,
//! values computed in stage `s` are only visible after `s+1` clock edges,
//! and the result emerges after `stages` clocks (the paper's "Latency
//! (Clocks)" column). Verified bit-exact against the golden model.

pub mod vcd;

use std::collections::{BTreeMap, VecDeque};

use crate::synth::netlist::Netlist;
use crate::synth::pipeline::PipelineAssignment;

/// One in-flight transaction.
#[derive(Clone, Debug)]
struct Txn {
    /// Clocks since insertion (stage index currently being computed).
    age: u32,
    /// Node values computed so far (by stage).
    vals: Vec<i64>,
    /// Which nodes have been computed.
    done: Vec<bool>,
    input: i64,
}

/// Cycle-accurate simulator for a pipelined feed-forward netlist.
pub struct RtlSim<'a> {
    net: &'a Netlist,
    pipe: &'a PipelineAssignment,
    in_flight: VecDeque<Txn>,
    /// Total clock edges simulated.
    pub cycles: u64,
    /// Total results produced.
    pub results: u64,
}

impl<'a> RtlSim<'a> {
    pub fn new(net: &'a Netlist, pipe: &'a PipelineAssignment) -> Self {
        assert_eq!(net.nodes.len(), pipe.stage_of.len());
        RtlSim { net, pipe, in_flight: VecDeque::new(), cycles: 0, results: 0 }
    }

    pub fn latency(&self) -> u32 {
        self.pipe.stages
    }

    /// True when no transactions are in flight.
    pub fn idle(&self) -> bool {
        self.in_flight.is_empty()
    }

    /// The value currently visible on each node's output wires: the
    /// combinational cloud of stage `s` shows the transaction whose age
    /// is `s` (its registered inputs arrived this cycle). Used by the
    /// VCD dumper.
    pub fn visible_values(&self) -> Vec<Option<i64>> {
        let mut out = vec![None; self.net.nodes.len()];
        let last = self.pipe.stages - 1;
        for txn in &self.in_flight {
            let occupied = txn.age.min(last);
            for (id, &s) in self.pipe.stage_of.iter().enumerate() {
                if s == occupied && txn.done[id] {
                    out[id] = Some(txn.vals[id]);
                }
            }
        }
        out
    }

    /// Advance one clock edge. `input`: the word accepted this cycle (the
    /// pipeline accepts one per clock; `None` inserts a bubble). Returns
    /// the output word registered at this edge, if one completes.
    pub fn clock(&mut self, input: Option<i64>) -> Option<i64> {
        self.cycles += 1;

        // Age the pipeline and compute each transaction's next stage.
        let mut flying = std::mem::take(&mut self.in_flight);
        for txn in flying.iter_mut() {
            txn.age += 1;
            if txn.age < self.pipe.stages {
                self.compute_stage(txn, txn.age);
            }
        }
        self.in_flight = flying;

        // Retire the oldest transaction if it has passed the output reg.
        let out = if self
            .in_flight
            .front()
            .map(|t| t.age >= self.pipe.stages)
            .unwrap_or(false)
        {
            let t = self.in_flight.pop_front().unwrap();
            self.results += 1;
            Some(self.net.outputs.iter().map(|&o| t.vals[o]).next().unwrap())
        } else {
            None
        };

        // Accept the new input and compute its stage-0 logic.
        if let Some(x) = input {
            let mut txn = Txn {
                age: 0,
                vals: vec![0; self.net.nodes.len()],
                done: vec![false; self.net.nodes.len()],
                input: x,
            };
            self.compute_stage(&mut txn, 0);
            self.in_flight.push_back(txn);
        }

        out
    }

    /// Evaluate all nodes assigned to `stage` for this transaction, from
    /// the (registered) values of earlier stages — exactly what the
    /// stage's combinational cloud does on a clock edge.
    fn compute_stage(&self, txn: &mut Txn, stage: u32) {
        let mut inputs = BTreeMap::new();
        inputs.insert("x".to_string(), txn.input);
        for (id, &s) in self.pipe.stage_of.iter().enumerate() {
            if s == stage {
                debug_assert!(!txn.done[id]);
                // Pipeline legality: predecessors live in stages <= s.
                debug_assert!(
                    self.net.nodes[id].inputs.iter().all(|&i| txn.done[i]),
                    "stage {stage} node {id} reads an uncomputed value"
                );
                txn.vals[id] = self.net.eval_node_at(id, &txn.vals, &inputs);
                txn.done[id] = true;
            }
        }
    }

    /// Run a whole batch through the pipeline back-to-back; returns the
    /// outputs in order plus the cycle count it took.
    pub fn run_batch(&mut self, xs: &[i64]) -> (Vec<i64>, u64) {
        let start = self.cycles;
        let mut out = Vec::with_capacity(xs.len());
        let mut it = xs.iter();
        loop {
            let next = it.next().copied();
            if next.is_none() && self.in_flight.is_empty() {
                break;
            }
            if let Some(y) = self.clock(next) {
                out.push(y);
            }
        }
        (out, self.cycles - start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::datapath::build_tanh_datapath;
    use crate::synth::pipeline::assign_stages;
    use crate::tanh::golden::tanh_golden_batch;
    use crate::tanh::TanhConfig;

    #[test]
    fn pipelined_sim_matches_golden_8bit_exhaustive() {
        let cfg = TanhConfig::s3_5();
        let net = build_tanh_datapath(&cfg);
        let xs: Vec<i64> = (-256..256).collect();
        let want = tanh_golden_batch(&xs, &cfg);
        for stages in [1u32, 2, 4, 7] {
            let pipe = assign_stages(&net, stages);
            let mut sim = RtlSim::new(&net, &pipe);
            let (got, _) = sim.run_batch(&xs);
            assert_eq!(got, want, "stages={stages}");
        }
    }

    #[test]
    fn throughput_one_per_clock() {
        let cfg = TanhConfig::s3_12();
        let net = build_tanh_datapath(&cfg);
        let pipe = assign_stages(&net, 7);
        let mut sim = RtlSim::new(&net, &pipe);
        let xs: Vec<i64> = (0..1000).collect();
        let (got, cycles) = sim.run_batch(&xs);
        assert_eq!(got.len(), 1000);
        // N results in N + latency cycles.
        assert_eq!(cycles, 1000 + 7);
    }

    #[test]
    fn latency_matches_stage_count() {
        let cfg = TanhConfig::s3_12();
        let net = build_tanh_datapath(&cfg);
        for stages in [1u32, 2, 7] {
            let pipe = assign_stages(&net, stages);
            let mut sim = RtlSim::new(&net, &pipe);
            let mut first_out_at = None;
            for c in 0..(stages as usize + 2) {
                let out = sim.clock(if c == 0 { Some(1000) } else { None });
                if out.is_some() && first_out_at.is_none() {
                    first_out_at = Some(c as u32);
                }
            }
            // Input at clock 0 emerges on the edge `stages` clocks later.
            assert_eq!(first_out_at, Some(stages), "stages={stages}");
        }
    }

    #[test]
    fn bubbles_preserve_order_and_values() {
        let cfg = TanhConfig::s3_5();
        let net = build_tanh_datapath(&cfg);
        let pipe = assign_stages(&net, 3);
        let mut sim = RtlSim::new(&net, &pipe);
        let xs = [5i64, -17, 100];
        let want = tanh_golden_batch(&xs, &cfg);
        let mut got = Vec::new();
        // Insert with bubbles between.
        let pattern = [Some(5i64), None, Some(-17), None, None, Some(100)];
        for &p in &pattern {
            if let Some(y) = sim.clock(p) {
                got.push(y);
            }
        }
        for _ in 0..8 {
            if let Some(y) = sim.clock(None) {
                got.push(y);
            }
        }
        assert_eq!(got, want);
    }
}
