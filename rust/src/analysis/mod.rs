//! Error-analysis harness: exhaustive/sampled accuracy sweeps over any
//! fixed-point tanh implementation (the Table II engine, also used for
//! baseline comparisons and ablations), plus the static datapath
//! verifier ([`verify`]) that proves overflow-freedom, SIMD-gate
//! soundness and worst-case error bounds without running a sweep.

pub mod domain;
pub mod verify;

use crate::fixed::{ErrorStats, QFormat};

/// Any fixed-point tanh implementation: input word -> output word.
pub trait TanhImpl {
    fn eval_word(&self, x: i64) -> i64;
    fn in_format(&self) -> QFormat;
    fn out_format(&self) -> QFormat;
    fn name(&self) -> String;

    /// Batch evaluation into a caller buffer. The default is the plain
    /// per-word loop; implementations with a hoisted or vectorized
    /// batch kernel override it (must stay bit-exact vs `eval_word`).
    fn eval_batch_words(&self, xs: &[i64], out: &mut [i64]) {
        assert_eq!(xs.len(), out.len());
        for (o, &x) in out.iter_mut().zip(xs) {
            *o = self.eval_word(x);
        }
    }

    /// Hardware cost summary for comparison tables (optional).
    fn cost(&self) -> Cost {
        Cost::default()
    }
}

/// Coarse hardware cost descriptors for baseline comparison tables.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Cost {
    /// Bits of ROM/LUT storage.
    pub lut_bits: u64,
    /// Number of multipliers on the critical path datapath.
    pub multipliers: u32,
    /// Number of adders/subtractors.
    pub adders: u32,
    /// Rough comparator/mux count (range selection logic).
    pub comparators: u32,
}

impl TanhImpl for crate::tanh::TanhUnit {
    fn eval_word(&self, x: i64) -> i64 {
        self.eval(x)
    }

    fn eval_batch_words(&self, xs: &[i64], out: &mut [i64]) {
        self.eval_batch_into(xs, out);
    }

    fn in_format(&self) -> QFormat {
        self.config().in_format()
    }

    fn out_format(&self) -> QFormat {
        self.config().out_format()
    }

    fn name(&self) -> String {
        format!("velocity-factor ({})", self.config().describe())
    }

    fn cost(&self) -> Cost {
        let cfg = self.config();
        let lut_bits: u64 = cfg
            .group_positions()
            .iter()
            .map(|g| (1u64 << g.len()) * (cfg.lut_bits as u64 + 1))
            .sum();
        Cost {
            lut_bits,
            // (groups-1) chain multipliers + 2 per NR stage + recompose.
            multipliers: cfg.num_groups() - 1 + 2 * cfg.nr_stages + 1,
            adders: 2 + cfg.nr_stages, // num, seed, 2-d*x per stage
            comparators: 1,            // saturation compare
        }
    }
}

/// Exhaustive sweep over the full input domain of `imp`.
pub fn exhaustive_error(imp: &dyn TanhImpl) -> ErrorStats {
    let w = imp.in_format().width();
    let half = 1i64 << (w - 1);
    sweep_error(imp, (-half..half).collect::<Vec<_>>().as_slice())
}

/// Error sweep over explicit input words.
pub fn sweep_error(imp: &dyn TanhImpl, xs: &[i64]) -> ErrorStats {
    let inf = imp.in_format();
    let outf = imp.out_format();
    ErrorStats::collect(xs.iter().map(|&x| {
        let got = outf.dequantize(imp.eval_word(x));
        let want = inf.dequantize(x).tanh();
        (x, got, want)
    }))
}

/// Per-region error breakdown (pass / processing / saturation, after
/// Zamanlooy's region taxonomy which the paper's §II discusses).
#[derive(Debug, Clone)]
pub struct RegionReport {
    pub pass: ErrorStats,
    pub processing: ErrorStats,
    pub saturation: ErrorStats,
}

pub fn region_error(imp: &dyn TanhImpl) -> RegionReport {
    let inf = imp.in_format();
    let w = inf.width();
    let half = 1i64 << (w - 1);
    // Pass region |x| < 0.25 (tanh x ~ x within 0.52%), saturation where
    // |tanh| > 0.996 (|x| > 3.1), processing between.
    let lo = inf.quantize(0.25, crate::fixed::Round::Nearest);
    let hi = inf.quantize(3.1, crate::fixed::Round::Nearest).min(half - 1);
    let (mut pass, mut proc, mut sat) = (vec![], vec![], vec![]);
    for x in -half..half {
        let a = x.abs();
        if a < lo {
            pass.push(x);
        } else if a <= hi {
            proc.push(x);
        } else {
            sat.push(x);
        }
    }
    RegionReport {
        pass: sweep_error(imp, &pass),
        processing: sweep_error(imp, &proc),
        saturation: sweep_error(imp, &sat),
    }
}

/// ULP-level histogram of output error (how many words are exact, off by
/// one lsb, etc.) — a sharper view than max error alone.
pub fn ulp_histogram(imp: &dyn TanhImpl, cap: i64) -> Vec<(i64, u64)> {
    let inf = imp.in_format();
    let outf = imp.out_format();
    let w = inf.width();
    let half = 1i64 << (w - 1);
    let mut counts: std::collections::BTreeMap<i64, u64> = Default::default();
    for x in -half..half {
        let got = imp.eval_word(x);
        let want = outf.quantize(inf.dequantize(x).tanh(), crate::fixed::Round::Nearest);
        let ulp = (got - want).abs().min(cap);
        *counts.entry(ulp).or_default() += 1;
    }
    counts.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tanh::{TanhConfig, TanhUnit};

    #[test]
    fn exhaustive_16bit_matches_table2_band() {
        let unit = TanhUnit::new(TanhConfig::s3_12()).unwrap();
        let stats = exhaustive_error(&unit);
        assert!(stats.max_abs < 7.7e-5, "{}", stats.max_abs);
        assert!(stats.count == 65536);
    }

    #[test]
    fn region_errors_ordered() {
        let unit = TanhUnit::new(TanhConfig::s3_5()).unwrap();
        let rep = region_error(&unit);
        // Saturation region error bounded by ~1 lsb by construction.
        assert!(rep.saturation.max_abs <= unit.out_format().lsb() * 1.01);
        assert!(rep.pass.count > 0 && rep.processing.count > 0);
    }

    #[test]
    fn ulp_histogram_mostly_exact() {
        let unit = TanhUnit::new(TanhConfig::s3_5()).unwrap();
        let hist = ulp_histogram(&unit, 4);
        let total: u64 = hist.iter().map(|(_, c)| c).sum();
        let exact = hist.iter().find(|(u, _)| *u == 0).map(|(_, c)| *c).unwrap_or(0);
        let within1: u64 = hist.iter().filter(|(u, _)| *u <= 1).map(|(_, c)| *c).sum();
        assert_eq!(total, 512);
        assert!(exact * 10 >= total * 6, "exact {exact}/{total}"); // >= 60%
        assert!(within1 * 100 >= total * 95, "within1 {within1}/{total}");
    }

    #[test]
    fn cost_model_16bit() {
        let unit = TanhUnit::new(TanhConfig::s3_12()).unwrap();
        let c = unit.cost();
        // 4 LUTs: 16+16+16+8 entries * 19 bits.
        assert_eq!(c.lut_bits, (16 + 16 + 16 + 8) * 19);
        // 3 chain + 6 NR + 1 recompose = 10.
        assert_eq!(c.multipliers, 10);
    }
}
