//! Abstract domain for the static datapath verifier: saturating i128
//! intervals paired with known-low-zero-bit tracking.
//!
//! Every intermediate of the §5 fixed-point datapath is an `i64`; the
//! verifier re-runs the datapath over *sets* of words instead of words,
//! using [`Iv`] (an inclusive `[lo, hi]` interval carried in `i128`, so
//! overflow of the concrete `i64` is representable rather than UB) and
//! [`AbsWord`] (an interval plus the number of low bits proven zero —
//! the component that shows a shift is an exact division, not a
//! truncation).
//!
//! Soundness discipline: every transfer function returns a superset of
//! the concrete results. Arithmetic that would overflow even the i128
//! carrier saturates to `±SAT_LIMIT` (far outside the i64 range), so a
//! mutated/absurd config degrades to "provably does not fit in i64" —
//! a failed obligation — never to a silently wrapped bound.

/// Saturation rail for the i128 carrier: big enough that any real
/// datapath value is exact, small enough that sums of saturated values
/// cannot wrap i128.
pub const SAT_LIMIT: i128 = 1 << 120;

fn sat(v: i128) -> i128 {
    v.clamp(-SAT_LIMIT, SAT_LIMIT)
}

fn sat_add(a: i128, b: i128) -> i128 {
    sat(a.saturating_add(b))
}

fn sat_mul(a: i128, b: i128) -> i128 {
    match a.checked_mul(b) {
        Some(p) => sat(p),
        None => {
            if (a < 0) == (b < 0) {
                SAT_LIMIT
            } else {
                -SAT_LIMIT
            }
        }
    }
}

/// Inclusive integer interval `[lo, hi]` over a saturating i128 carrier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Iv {
    pub lo: i128,
    pub hi: i128,
}

impl Iv {
    pub fn new(lo: i128, hi: i128) -> Iv {
        assert!(lo <= hi, "interval [{lo}, {hi}] is empty");
        Iv { lo: sat(lo), hi: sat(hi) }
    }

    pub fn point(v: i128) -> Iv {
        Iv::new(v, v)
    }

    pub fn add(self, o: Iv) -> Iv {
        Iv { lo: sat_add(self.lo, o.lo), hi: sat_add(self.hi, o.hi) }
    }

    pub fn sub(self, o: Iv) -> Iv {
        Iv { lo: sat_add(self.lo, -o.hi), hi: sat_add(self.hi, -o.lo) }
    }

    pub fn neg(self) -> Iv {
        Iv { lo: -self.hi, hi: -self.lo }
    }

    /// Product interval: min/max over the four sign corners.
    pub fn mul(self, o: Iv) -> Iv {
        let c = [
            sat_mul(self.lo, o.lo),
            sat_mul(self.lo, o.hi),
            sat_mul(self.hi, o.lo),
            sat_mul(self.hi, o.hi),
        ];
        Iv {
            lo: c.iter().copied().min().unwrap(),
            hi: c.iter().copied().max().unwrap(),
        }
    }

    /// Left shift (exact scaling by `2^s`, saturating).
    pub fn shl(self, s: u32) -> Iv {
        if s >= 120 {
            // Any nonzero value saturates; zero stays zero.
            return Iv {
                lo: if self.lo < 0 { -SAT_LIMIT } else { 0 },
                hi: if self.hi > 0 { SAT_LIMIT } else { 0 },
            };
        }
        Iv {
            lo: sat(self.lo.saturating_mul(1i128 << s)),
            hi: sat(self.hi.saturating_mul(1i128 << s)),
        }
    }

    /// Arithmetic right shift (floor division by `2^s`), the semantics
    /// of `>>` on the concrete i64 datapath. Monotone, so the interval
    /// maps endpoint-to-endpoint.
    pub fn shr(self, s: u32) -> Iv {
        let s = s.min(127);
        Iv { lo: self.lo >> s, hi: self.hi >> s }
    }

    /// Smallest interval covering both.
    pub fn join(self, o: Iv) -> Iv {
        Iv { lo: self.lo.min(o.lo), hi: self.hi.max(o.hi) }
    }

    /// Intersection, if non-empty. Sound refinement: when two
    /// independent analyses both bound the same concrete value, the
    /// value lies in the overlap.
    pub fn intersect(self, o: Iv) -> Option<Iv> {
        let lo = self.lo.max(o.lo);
        let hi = self.hi.min(o.hi);
        if lo <= hi {
            Some(Iv { lo, hi })
        } else {
            None
        }
    }

    pub fn clamp_to(self, lo: i128, hi: i128) -> Iv {
        Iv { lo: self.lo.clamp(lo, hi), hi: self.hi.clamp(lo, hi) }
    }

    /// Does every value fit in i64?
    pub fn fits_i64(self) -> bool {
        self.lo >= i64::MIN as i128 && self.hi <= i64::MAX as i128
    }

    /// Does every value fit in a signed `bits`-bit word (the low-32
    /// exactness condition of `_mm256_mul_epi32` for `bits = 32`)?
    pub fn fits_signed(self, bits: u32) -> bool {
        if bits == 0 || bits > 127 {
            return false;
        }
        let half = 1i128 << (bits - 1);
        self.lo >= -half && self.hi < half
    }

    pub fn is_nonneg(self) -> bool {
        self.lo >= 0
    }

    pub fn width(self) -> i128 {
        self.hi - self.lo
    }
}

/// An abstract datapath word: value interval plus the number of low
/// bits known to be zero for *every* concrete value in the set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AbsWord {
    pub iv: Iv,
    pub low_zeros: u32,
}

/// Cap on tracked low zeros (an i64 word has at most 63 value bits).
const MAX_LZ: u32 = 63;

impl AbsWord {
    pub fn exact(v: i128) -> AbsWord {
        let lz = if v == 0 { MAX_LZ } else { v.trailing_zeros().min(MAX_LZ) };
        AbsWord { iv: Iv::point(v), low_zeros: lz }
    }

    /// A plain range: nothing known about low bits unless degenerate.
    pub fn range(lo: i128, hi: i128) -> AbsWord {
        if lo == hi {
            AbsWord::exact(lo)
        } else {
            AbsWord { iv: Iv::new(lo, hi), low_zeros: 0 }
        }
    }

    pub fn from_iv(iv: Iv) -> AbsWord {
        AbsWord::range(iv.lo, iv.hi)
    }

    /// `a + b`: a sum keeps the common low-zero run.
    pub fn add(self, o: AbsWord) -> AbsWord {
        AbsWord {
            iv: self.iv.add(o.iv),
            low_zeros: self.low_zeros.min(o.low_zeros),
        }
    }

    pub fn sub(self, o: AbsWord) -> AbsWord {
        AbsWord {
            iv: self.iv.sub(o.iv),
            low_zeros: self.low_zeros.min(o.low_zeros),
        }
    }

    /// `a * b`: low-zero runs add (2^i · 2^j divides the product).
    pub fn mul(self, o: AbsWord) -> AbsWord {
        AbsWord {
            iv: self.iv.mul(o.iv),
            low_zeros: (self.low_zeros + o.low_zeros).min(MAX_LZ),
        }
    }

    pub fn shl(self, s: u32) -> AbsWord {
        AbsWord {
            iv: self.iv.shl(s),
            low_zeros: (self.low_zeros + s).min(MAX_LZ),
        }
    }

    /// Arithmetic right shift. If the operand has `s` known low zeros
    /// the shift is an exact division (no information is destroyed and
    /// `low_zeros` just drops by `s`); otherwise it is a floor and all
    /// low-bit knowledge is lost.
    pub fn shr(self, s: u32) -> AbsWord {
        let low_zeros =
            if self.low_zeros >= s { self.low_zeros - s } else { 0 };
        AbsWord { iv: self.iv.shr(s), low_zeros }
    }

    /// Is `>> s` an exact division (not a truncation) for every value?
    pub fn shr_exact(self, s: u32) -> bool {
        self.low_zeros >= s
    }

    /// Refine the interval with an independent bound on the same value.
    pub fn refine(self, iv: Iv) -> AbsWord {
        match self.iv.intersect(iv) {
            Some(t) => AbsWord { iv: t, low_zeros: self.low_zeros },
            // Disjoint bounds can only come from slack mis-accounting
            // upstream; keep the original (sound) interval.
            None => self,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_arithmetic_covers_concrete() {
        let a = Iv::new(-3, 5);
        let b = Iv::new(2, 4);
        let s = a.add(b);
        let p = a.mul(b);
        for x in -3i128..=5 {
            for y in 2i128..=4 {
                assert!(s.lo <= x + y && x + y <= s.hi);
                assert!(p.lo <= x * y && x * y <= p.hi);
            }
        }
    }

    #[test]
    fn shr_is_floor_like_the_datapath() {
        let a = Iv::new(-7, 9);
        let r = a.shr(1);
        for x in -7i128..=9 {
            let c = x >> 1;
            assert!(r.lo <= c && c <= r.hi, "x={x} -> {c} not in {r:?}");
        }
        assert_eq!(r.lo, -4); // floor(-7/2), not trunc
    }

    #[test]
    fn saturation_instead_of_wrap() {
        let big = Iv::point(1 << 100);
        let p = big.mul(big);
        assert_eq!(p.hi, SAT_LIMIT);
        assert!(!p.fits_i64());
        let neg = big.neg().mul(big);
        assert_eq!(neg.lo, -SAT_LIMIT);
    }

    #[test]
    fn fits_checks() {
        assert!(Iv::new(-(1 << 62), 1 << 62).fits_i64());
        assert!(!Iv::point((1 << 63) + 1).fits_i64());
        assert!(Iv::new(-(1 << 31), (1 << 31) - 1).fits_signed(32));
        assert!(!Iv::point(1 << 31).fits_signed(32));
    }

    #[test]
    fn low_zeros_through_ops() {
        let a = AbsWord::exact(8); // 3 low zeros
        assert_eq!(a.low_zeros, 3);
        let b = AbsWord::exact(4);
        assert_eq!(a.mul(b).low_zeros, 5);
        assert_eq!(a.add(b).low_zeros, 2);
        assert!(a.shr_exact(3));
        assert!(!a.shr_exact(4));
        assert_eq!(a.shl(2).low_zeros, 5);
        let r = AbsWord::range(1, 10);
        assert_eq!(r.low_zeros, 0);
        assert_eq!(r.shr(2).low_zeros, 0);
    }

    #[test]
    fn intersect_and_refine() {
        let a = Iv::new(0, 100);
        let b = Iv::new(50, 200);
        assert_eq!(a.intersect(b), Some(Iv::new(50, 100)));
        assert_eq!(a.intersect(Iv::new(200, 300)), None);
        let w = AbsWord::range(0, 100).refine(Iv::new(50, 70));
        assert_eq!(w.iv, Iv::new(50, 70));
    }
}
