//! Static datapath verifier: abstract interpretation of the §5
//! fixed-point pipeline over the [`super::domain`] interval ×
//! known-low-bits domain.
//!
//! For any [`TanhConfig`] (valid or deliberately broken) the verifier
//! statically proves, without evaluating a single word:
//!
//! 1. **Overflow-freedom** — every intermediate of every stage (LUT
//!    product chain, Newton–Raphson iterations, recompose) fits in
//!    `i64`, for *all* `i64` input words (gather addresses are formed
//!    bit-by-bit, so even garbage lanes read real table entries).
//! 2. **SIMD-gate soundness** — every config the AVX2 eligibility gate
//!    admits has provably exact low-32 multiplies and provably
//!    non-negative operands at every logical-shift site, so the vector
//!    kernel is bit-exact against the scalar datapath. The gate's
//!    bounds ([`SIMD_MIN_LUT_MARGIN`] etc.) live here, next to the
//!    proof that justifies them.
//! 3. **Saturation coverage** — the clip threshold is high enough that
//!    the saturated region contributes at most one output lsb of error.
//! 4. **A static worst-case error bound** (in output lsb, vs real
//!    `tanh`) that must dominate the empirically measured max error —
//!    checked against the exhaustive sweep by `tests/verify_datapath.rs`
//!    and the `verify-datapath` CLI subcommand.
//!
//! ## Newton–Raphson: residual recurrence, not interval iteration
//!
//! Naive interval propagation through NR diverges: interval arithmetic
//! cannot see that NR *contracts* (the classic dependency problem), so
//! three iterations inflate a few-ulp reciprocal into a thousands-wide
//! interval. Instead the verifier tracks the residual
//! `eps_k >= |1 - D*X_k|` (with `D = d/2^M in (1/2, 1]`,
//! `X_k = xr_k/2^M`). The seed `X_0 = S - 2D` is exact, so `eps_0` is
//! the max of the quadratic `|1 - S*D + 2*D^2|` over the `D` interval
//! (endpoints + vertex). Each stage performs two `+2^(M-1)`-then-shift
//! roundings (`|r| <= 2^(-M-1)` each), giving
//!
//! ```text
//! 1 - D*X' = (1 - D*X)^2 + D*X*r1 - D*r2
//! eps'    <= eps^2 + (2 + eps) * 2^(-M-1)
//! ```
//!
//! which is pointwise in `D` — width-free, so it converges exactly like
//! the hardware does. The integer `xr_k` then lies in
//! `2^(2M) * [(1-eps)/d_hi, (1+eps)/d_lo]`, and that bound *refines*
//! the naive interval (both are sound; the intersection is, too). The
//! naive interval remains the fallback when the residual diverges
//! (`eps >= 1`, e.g. a mutated seed constant), keeping overflow checks
//! sound for arbitrarily broken datapaths.
//!
//! ## Error bound decomposition
//!
//! With `f^` the computed chain word and `r(f) = 2^out*(2^L-f)/(2^L+f)`
//! the exact output of an error-free back end (`r(2^L e^(-2a)) =
//! 2^out*tanh(a)` *identically* — the paper's eq. 9, so only rounding
//! contributes):
//!
//! * **term2** (chain): `|f^ - 2^L e^(-2a)| <= (2G-1)/2` words (G
//!   entries at <= 1/2 ulp each, G-1 chain roundings at <= 1/2, and
//!   every propagation factor is a velocity factor <= 1), times
//!   `max|r'| = 2^(out+1-L)`.
//! * **term1** (back end): on each of ~1024 `f`-subintervals, with
//!   `A = d*2^(L+1-M)` and truncation `tau = den - A in [0, 2^(L+1-M))`,
//!   `|V - r(f^)| <= 2^out * num * (eps/A + tau/(A*den))` (+`2^out(1+eps)/A`
//!   for the one's-complement numerator offset), plus the final 1/2 lsb
//!   recompose rounding. Subdividing keeps `num` and `eps` correlated:
//!   the residual is worst where `D -> 1`, which is exactly where
//!   `num -> 0`.
//! * **saturation**: `<= max(1, 2^out*(1 - tanh(th/2^in)) - 1)` lsb,
//!   `<= 1` whenever the threshold obligation holds.

use super::domain::{AbsWord, Iv};
use crate::tanh::{Subtractor, TanhConfig};
use crate::util::json::Json;
use std::collections::BTreeMap;

// ---------------------------------------------------------------------
// Preset catalog (shared by tests, CLI, and CI)
// ---------------------------------------------------------------------

/// The paper's two published operating points.
pub const SHIPPED_PRESETS: &[&str] = &["s3_12", "s3_5"];

/// Derived presets beyond the paper's points, served by
/// `server::named_config` and pinned by `tests/precision_presets.rs`.
pub const DERIVED_PRESETS: &[&str] = &["s2_6", "s3_6", "s3_9", "s4_10"];

/// Full catalog: shipped + derived preset names.
pub fn all_preset_names() -> Vec<&'static str> {
    let mut v = Vec::new();
    v.extend_from_slice(SHIPPED_PRESETS);
    v.extend_from_slice(DERIVED_PRESETS);
    v
}

// ---------------------------------------------------------------------
// The SIMD eligibility gate (single source of truth)
// ---------------------------------------------------------------------

/// The AVX2 kernel cannot vectorize the `nr = 0` float reference
/// divider, so at least one NR stage is required.
pub const SIMD_MIN_NR_STAGES: u32 = 1;

/// Minimum `lut_bits - out_frac` margin. The verifier proves margin 2
/// suffices even for the one's-complement `num = -1` corner
/// (`2^(shift-1) = 2^(L+M-out) >= xr_hi ~ 2^(M+1)(1+eps)`); the shipped
/// gate keeps one extra bit of slack.
pub const SIMD_MIN_LUT_MARGIN: u32 = 3;

/// Maximum LUT precision: keeps every `_mm256_mul_epi32` factor on the
/// chain and recompose sites below `2^28` (provable ceiling is `2^31`;
/// the gate leaves headroom).
pub const SIMD_MAX_LUT_BITS: u32 = 26;

/// Maximum multiplier precision: bounds `d` and the NR iterates below
/// `2^28` (provable ceiling `xr < 2^(M+2) <= 2^31` at `M = 29`).
pub const SIMD_MAX_MULT_BITS: u32 = 26;

/// The eligibility predicate the runtime dispatch uses
/// (`tanh::simd::datapath_eligible` delegates here, so gate and proof
/// cannot drift). Soundness — "admitted implies verifier-provable" —
/// is enforced by the grid sweep in `tests/verify_datapath.rs`.
pub fn simd_gate(cfg: &TanhConfig) -> bool {
    cfg.nr_stages >= SIMD_MIN_NR_STAGES
        && cfg.lut_bits >= cfg.out_frac + SIMD_MIN_LUT_MARGIN
        && cfg.lut_bits <= SIMD_MAX_LUT_BITS
        && cfg.mult_bits <= SIMD_MAX_MULT_BITS
}

// ---------------------------------------------------------------------
// Parameters under verification (the mutation surface)
// ---------------------------------------------------------------------

/// The constants the verifier reasons about. [`Self::from_config`]
/// fills them exactly as the real datapath derives them; mutation
/// tests override individual fields to prove each obligation can fail.
#[derive(Clone, Debug)]
pub struct DatapathParams {
    pub cfg: TanhConfig,
    /// Saturation compare threshold (input magnitude words).
    pub sat_threshold: i64,
    /// NR linear-seed constant (`2.75 * 2^M` in the real datapath).
    pub seed_const: i64,
    /// Signed width of the vector low-multiply (32 for
    /// `_mm256_mul_epi32`; mutations truncate it further).
    pub mul_keep_bits: u32,
    /// Require the SIMD obligations even if the gate rejects the
    /// config — models forcing an ineligible config down the AVX2 path.
    pub force_simd: bool,
}

impl DatapathParams {
    pub fn from_config(cfg: &TanhConfig) -> DatapathParams {
        let seed_const = if cfg.nr_stages >= 1 && cfg.mult_bits >= 2 {
            cfg.nr_seed_const()
        } else {
            0
        };
        DatapathParams {
            cfg: *cfg,
            sat_threshold: cfg.sat_threshold(),
            seed_const,
            mul_keep_bits: 32,
            force_simd: false,
        }
    }
}

// ---------------------------------------------------------------------
// Report types
// ---------------------------------------------------------------------

/// One proof obligation: a named fact the verifier either proved or
/// could not prove for this config.
#[derive(Clone, Debug)]
pub struct Obligation {
    pub name: &'static str,
    pub proved: bool,
    pub detail: String,
}

/// One row of the per-stage interval table (for the CLI report).
#[derive(Clone, Debug)]
pub struct StageRange {
    pub stage: String,
    pub lo: i128,
    pub hi: i128,
    pub low_zeros: u32,
}

/// The verifier's verdict for one parameter set.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub config: TanhConfig,
    /// Core obligations (overflow, shifts, saturation, convergence,
    /// gate soundness). All must hold for [`Self::proven`].
    pub obligations: Vec<Obligation>,
    /// SIMD-specific obligations; required only when the gate admits
    /// the config (or `force_simd` demands it).
    pub simd_obligations: Vec<Obligation>,
    pub stages: Vec<StageRange>,
    /// Did the eligibility gate admit this config?
    pub simd_admitted: bool,
    /// Did every SIMD obligation hold?
    pub simd_provable: bool,
    /// Final NR residual bound `eps >= |1 - D*X|` (None for `nr = 0`).
    pub nr_residual: Option<f64>,
    /// Static worst-case error bound in output lsb vs real tanh
    /// (None when not requested or when a prerequisite failed).
    pub static_max_ulp: Option<f64>,
}

impl VerifyReport {
    /// Every core obligation proved (gate soundness is itself a core
    /// obligation, so an admitted-but-unprovable config is unproven).
    pub fn proven(&self) -> bool {
        self.obligations.iter().all(|o| o.proved)
    }

    pub fn failed(&self) -> Vec<&Obligation> {
        self.obligations
            .iter()
            .chain(self.simd_obligations.iter())
            .filter(|o| !o.proved)
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("config".into(), Json::Str(self.config.describe()));
        m.insert("proven".into(), Json::Bool(self.proven()));
        m.insert("simd_admitted".into(), Json::Bool(self.simd_admitted));
        m.insert("simd_provable".into(), Json::Bool(self.simd_provable));
        m.insert(
            "nr_residual".into(),
            self.nr_residual.map(Json::Num).unwrap_or(Json::Null),
        );
        m.insert(
            "static_max_ulp".into(),
            self.static_max_ulp.map(Json::Num).unwrap_or(Json::Null),
        );
        m.insert("obligations".into(), obligations_json(&self.obligations));
        m.insert(
            "simd_obligations".into(),
            obligations_json(&self.simd_obligations),
        );
        let stages = self
            .stages
            .iter()
            .map(|s| {
                let mut sm = BTreeMap::new();
                sm.insert("stage".into(), Json::Str(s.stage.clone()));
                sm.insert("lo".into(), Json::Num(s.lo as f64));
                sm.insert("hi".into(), Json::Num(s.hi as f64));
                sm.insert(
                    "low_zeros".into(),
                    Json::Num(s.low_zeros as f64),
                );
                Json::Obj(sm)
            })
            .collect();
        m.insert("stages".into(), Json::Arr(stages));
        Json::Obj(m)
    }
}

fn obligations_json(list: &[Obligation]) -> Json {
    Json::Arr(
        list.iter()
            .map(|o| {
                let mut m = BTreeMap::new();
                m.insert("name".into(), Json::Str(o.name.into()));
                m.insert("proved".into(), Json::Bool(o.proved));
                m.insert("detail".into(), Json::Str(o.detail.clone()));
                Json::Obj(m)
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------

/// Full verification of a config as the real datapath derives it,
/// including the static error bound.
pub fn verify(cfg: &TanhConfig) -> VerifyReport {
    verify_params(&DatapathParams::from_config(cfg), true)
}

/// Cheap structural verification (no error-bound subdivision) — the
/// construction-time check behind the `TanhUnit::new` /
/// `SigmoidUnit::new` debug assertions. `O(groups + nr_stages)`.
pub fn verify_safety(cfg: &TanhConfig) -> Result<(), String> {
    let rep = verify_params(&DatapathParams::from_config(cfg), false);
    if rep.proven() {
        Ok(())
    } else {
        let fails: Vec<String> = rep
            .failed()
            .iter()
            .map(|o| format!("{}: {}", o.name, o.detail))
            .collect();
        Err(format!(
            "datapath verifier rejected {}: {}",
            cfg.describe(),
            fails.join("; ")
        ))
    }
}

fn push(
    list: &mut Vec<Obligation>,
    name: &'static str,
    proved: bool,
    detail: String,
) -> bool {
    list.push(Obligation { name, proved, detail });
    proved
}

/// Max of `|1 - S*D + 2*D^2|` over `D in [d_lo, d_hi]` — the seed
/// residual. The quadratic is convex, so the max is at an endpoint;
/// the vertex is included for the absolute value of a negative dip
/// (possible for mutated seeds).
fn seed_residual(s: f64, d_lo: f64, d_hi: f64) -> f64 {
    let r = |d: f64| (1.0 - s * d + 2.0 * d * d).abs();
    let mut eps = r(d_lo).max(r(d_hi));
    let vertex = s / 4.0;
    if d_lo < vertex && vertex < d_hi {
        eps = eps.max(r(vertex));
    }
    eps * (1.0 + 1e-12)
}

/// One residual-recurrence step: two `2^(-M-1)` roundings per stage.
fn residual_step(eps: f64, m: u32) -> f64 {
    let half_ulp = 0.5 * 2f64.powi(-(m as i32));
    (eps * eps + (2.0 + eps) * half_ulp) * (1.0 + 1e-12)
}

/// Run the abstract interpreter over `p` and discharge every
/// obligation. `with_error_bound` additionally computes the subdivided
/// static worst-case ulp bound (the expensive part).
pub fn verify_params(
    p: &DatapathParams,
    with_error_bound: bool,
) -> VerifyReport {
    let cfg = &p.cfg;
    let l = cfg.lut_bits;
    let m = cfg.mult_bits;
    let out = cfg.out_frac;
    let nr = cfg.nr_stages;
    let kb = p.mul_keep_bits;

    let mut obs: Vec<Obligation> = Vec::new();
    let mut simd: Vec<Obligation> = Vec::new();
    let mut stages: Vec<StageRange> = Vec::new();

    let record = |stages: &mut Vec<StageRange>, name: &str, w: AbsWord| {
        stages.push(StageRange {
            stage: name.to_string(),
            lo: w.iv.lo,
            hi: w.iv.hi,
            low_zeros: w.low_zeros,
        });
    };

    // --- structural shift obligations (everything else depends on
    // these, so a failure here ends the analysis) -------------------
    let mut structural = push(
        &mut obs,
        "chain_shift_valid",
        (1..=60).contains(&l),
        format!("lut_bits L={l} must be in 1..=60 (chain rounds by 2^(L-1), entries are u0.L)"),
    );
    structural &= push(
        &mut obs,
        "lut_grouping_valid",
        cfg.lut_group >= 1 && cfg.mag_bits() >= 1,
        format!(
            "lut_group = {} over {} magnitude bits",
            cfg.lut_group,
            cfg.mag_bits()
        ),
    );
    if nr >= 1 {
        structural &= push(
            &mut obs,
            "den_shift_valid",
            l + 1 >= m,
            format!("d = den >> (L+1-M) needs L+1 >= M (L={l}, M={m})"),
        );
        structural &= push(
            &mut obs,
            "seed_shift_valid",
            m >= 2,
            format!("seed constant 11 << (M-2) needs M >= 2 (M={m})"),
        );
        structural &= push(
            &mut obs,
            "recompose_shift_valid",
            (l + m + 1) as i64 > out as i64,
            format!(
                "recompose shift L+M+1-out = {} must be >= 1",
                l as i64 + m as i64 + 1 - out as i64
            ),
        );
    }
    if !structural {
        return VerifyReport {
            config: *cfg,
            obligations: obs,
            simd_obligations: simd,
            stages,
            simd_admitted: simd_gate(cfg),
            simd_provable: false,
            nr_residual: None,
            static_max_ulp: None,
        };
    }

    let groups = cfg.num_groups();

    // --- LUT product chain -----------------------------------------
    // Entries are `round(2^L * e^(-2a)).min(2^L)`, i.e. in [0, 2^L],
    // and gather addresses are in range for ANY i64 input word (they
    // are assembled bit-by-bit), so this covers saturated/garbage
    // lanes the AVX2 kernel computes-then-blends as well.
    let one_l = AbsWord::exact(1).shl(l);
    let half_l = AbsWord::exact(1).shl(l - 1);
    let entry = AbsWord::from_iv(Iv::new(0, one_l.iv.hi));
    let mut f = entry;
    let mut chain_fits = true;
    let mut simd_chain_mul = true;
    let mut simd_chain_nonneg = true;
    for _ in 1..groups {
        let prod = f.mul(entry).add(half_l);
        chain_fits &= prod.iv.fits_i64();
        simd_chain_mul &=
            f.iv.fits_signed(kb) && entry.iv.fits_signed(kb);
        simd_chain_nonneg &= prod.iv.is_nonneg();
        f = prod.shr(l);
    }
    record(&mut stages, "f (lut chain, u0.L)", f);
    push(
        &mut obs,
        "chain_fits_i64",
        chain_fits,
        format!(
            "worst chain product ~2^{} with {} groups",
            2 * l + 1,
            groups
        ),
    );

    let num = match cfg.subtractor {
        Subtractor::Twos => one_l.sub(f),
        Subtractor::Ones => one_l.sub(AbsWord::exact(1)).sub(f),
    };
    let den = one_l.add(f);
    record(&mut stages, "num = 2^L - f", num);
    record(&mut stages, "den = 2^L + f", den);
    push(
        &mut obs,
        "front_end_fits_i64",
        num.iv.fits_i64() && den.iv.fits_i64(),
        format!("num in [{}, {}], den hi {}", num.iv.lo, num.iv.hi, den.iv.hi),
    );

    // --- back end --------------------------------------------------
    let mut nr_residual = None;
    let mut out_word;
    let mut nr_fits = true;
    let mut simd_nr_mul = true;
    let mut simd_nr_nonneg = true;
    let mut simd_rec_mul = true;
    let mut simd_rec_nonneg = true;
    let mut converges = true;
    let mut xr_final = AbsWord::exact(0);
    let mut d_saved = AbsWord::exact(0);

    if nr == 0 {
        // Float reference divider: rint(num/den * 2^out). num/den is
        // in (-2^-L, 1], so the word lands in [-1, 2^out] before the
        // clamp; no integer intermediate can overflow.
        out_word = AbsWord::from_iv(Iv::new(-1, Iv::point(1).shl(out).hi));
        record(&mut stages, "t = rint(num/den * 2^out)", out_word);
        // The vector kernel has no float divider at all.
        simd_nr_nonneg = false;
        simd_rec_nonneg = false;
    } else {
        let s_d = l + 1 - m;
        let d = den.shr(s_d);
        d_saved = d;
        record(&mut stages, "d = den >> (L+1-M), u1.M", d);

        let seed = AbsWord::exact(p.seed_const as i128);
        let mut xr = seed.sub(d.shl(1));
        nr_fits &= xr.iv.fits_i64();
        record(&mut stages, "xr0 = seed - 2d", xr);

        let two_m = 2f64.powi(m as i32);
        let d_lo_f = d.iv.lo as f64;
        let d_hi_f = d.iv.hi as f64;
        let mut eps = seed_residual(
            p.seed_const as f64 / two_m,
            d_lo_f / two_m,
            d_hi_f / two_m,
        );

        let half_m = AbsWord::exact(1).shl(m - 1);
        let two_m1 = AbsWord::exact(1).shl(m + 1);
        for k in 0..nr {
            let prod_t = d.mul(xr).add(half_m);
            nr_fits &= prod_t.iv.fits_i64();
            simd_nr_mul &=
                d.iv.fits_signed(kb) && xr.iv.fits_signed(kb);
            simd_nr_nonneg &= prod_t.iv.is_nonneg();
            let mut t = prod_t.shr(m);
            // Corner products see D_hi*X_hi ~ 2 although D*X ~ 1
            // pointwise (the dependency problem); the residual bound
            // D*X in [1-eps, 1+eps] plus the half-ulp rounding refines
            // t soundly for ANY eps (casts saturate, Iv::new clamps).
            t = t.refine(Iv::new(
                (two_m * (1.0 - eps) - 1.0).floor() as i128,
                (two_m * (1.0 + eps) + 1.0).ceil() as i128,
            ));
            let g = two_m1.sub(t);
            simd_nr_nonneg &= g.iv.is_nonneg();
            simd_nr_mul &= g.iv.fits_signed(kb);
            let prod_x = xr.mul(g).add(half_m);
            nr_fits &= prod_x.iv.fits_i64();
            simd_nr_nonneg &= prod_x.iv.is_nonneg();
            let mut next = prod_x.shr(m);

            eps = residual_step(eps, m);
            if eps < 1.0 && d.iv.lo > 0 {
                // X = (1 ± eps)/D pointwise => the integer iterate is
                // inside 2^(2M)*[(1-eps)/d_hi, (1+eps)/d_lo]; refine
                // the (divergence-prone) naive interval with it.
                let scale = two_m * two_m;
                let lo = (scale * (1.0 - eps) / d_hi_f * (1.0 - 1e-9))
                    .floor() as i128
                    - 1;
                let hi = (scale * (1.0 + eps) / d_lo_f * (1.0 + 1e-9))
                    .ceil() as i128
                    + 1;
                next = next.refine(Iv::new(lo, hi));
            }
            xr = next;
            record(&mut stages, &format!("xr{} (nr stage)", k + 1), xr);
        }
        converges = eps < 1.0;
        nr_residual = Some(eps);
        xr_final = xr;

        let shift = l + m + 1 - out;
        let o_round = AbsWord::exact(1).shl(shift - 1);
        let pre = num.mul(xr).add(o_round);
        nr_fits &= pre.iv.fits_i64();
        simd_rec_mul &=
            num.iv.fits_signed(kb) && xr.iv.fits_signed(kb);
        simd_rec_nonneg &= pre.iv.is_nonneg();
        record(&mut stages, "num*xr + 2^(shift-1)", pre);
        out_word = pre.shr(shift);
        record(&mut stages, "t = recompose >> shift", out_word);
    }
    out_word = AbsWord::from_iv(
        out_word.iv.clamp_to(0, cfg.out_max() as i128),
    );
    record(&mut stages, "clamp(0, out_max)", out_word);

    push(
        &mut obs,
        "back_end_fits_i64",
        nr_fits,
        format!(
            "NR + recompose intermediates, xr in [{}, {}]",
            xr_final.iv.lo, xr_final.iv.hi
        ),
    );
    if nr >= 1 {
        push(
            &mut obs,
            "nr_converges",
            converges,
            format!(
                "residual |1 - D*X| <= {:.3e} after {} stages (seed {})",
                nr_residual.unwrap_or(f64::NAN),
                nr,
                p.seed_const
            ),
        );
    }

    // --- saturation coverage ---------------------------------------
    // For n >= threshold the unit emits out_max = 2^out - 1; the error
    // vs 2^out*tanh(a) is |2^out*(1 - tanh(a)) - 1|, worst at the
    // threshold itself. <= 2 there bounds the whole region by 1 lsb.
    let mag = cfg.mag_bits().min(62);
    let domain_hi = 1i64 << mag;
    let sat_reachable = p.sat_threshold < domain_hi;
    let a0 = p.sat_threshold as f64 / 2f64.powi(cfg.in_frac as i32);
    let err_sat = 2f64.powi(out as i32) * (1.0 - a0.tanh());
    let sat_term = if sat_reachable { err_sat.max(2.0) - 1.0 } else { 0.0 };
    push(
        &mut obs,
        "saturation_covers_domain",
        !sat_reachable || (p.sat_threshold >= 1 && err_sat <= 2.0),
        format!(
            "threshold {} => 2^out*(1 - tanh({a0:.4})) = {err_sat:.4} (need <= 2)",
            p.sat_threshold
        ),
    );

    // --- SIMD obligations ------------------------------------------
    push(
        &mut simd,
        "simd_nr_stages",
        nr >= SIMD_MIN_NR_STAGES,
        format!("nr_stages = {nr}: the float divider is not vectorized"),
    );
    push(
        &mut simd,
        "simd_chain_mul_exact",
        simd_chain_mul,
        format!(
            "chain factors f, e in [0, 2^{l}] must fit signed {kb}-bit"
        ),
    );
    push(
        &mut simd,
        "simd_chain_shift_nonneg",
        simd_chain_nonneg,
        "f*e + 2^(L-1) >= 0 so the logical shift is arithmetic".into(),
    );
    push(
        &mut simd,
        "simd_nr_mul_exact",
        simd_nr_mul,
        format!(
            "NR factors d in [{}, {}], xr in [{}, {}], 2^(M+1)-t must fit signed {kb}-bit",
            d_saved.iv.lo, d_saved.iv.hi, xr_final.iv.lo, xr_final.iv.hi
        ),
    );
    push(
        &mut simd,
        "simd_nr_shift_nonneg",
        simd_nr_nonneg,
        "d*xr + 2^(M-1), 2^(M+1) - t and xr*(2^(M+1)-t) + 2^(M-1) stay >= 0"
            .into(),
    );
    push(
        &mut simd,
        "simd_recompose_mul_exact",
        simd_rec_mul,
        format!("num in [{}, {}] and xr must fit signed {kb}-bit",
                num.iv.lo, num.iv.hi),
    );
    push(
        &mut simd,
        "simd_recompose_shift_nonneg",
        simd_rec_nonneg,
        "num*xr + 2^(shift-1) >= 0 (one's-complement num >= -1 corner)"
            .into(),
    );
    let simd_provable = simd.iter().all(|o| o.proved);

    let simd_admitted = simd_gate(cfg);
    push(
        &mut obs,
        "simd_gate_sound",
        !simd_admitted || simd_provable,
        format!(
            "gate {} this config; SIMD obligations {}",
            if simd_admitted { "admits" } else { "rejects" },
            if simd_provable { "all proved" } else { "FAILED" }
        ),
    );
    if p.force_simd {
        push(
            &mut obs,
            "forced_simd_provable",
            simd_provable,
            "config forced down the AVX2 path".into(),
        );
    }

    // --- static error bound ----------------------------------------
    let mut static_max_ulp = None;
    if with_error_bound && chain_fits && nr_fits && converges {
        let eps_f = (2 * groups - 1) as f64 * 0.5;
        let term2 =
            eps_f * 2f64.powi(out as i32 + 1 - l as i32) * (1.0 + 1e-9);
        let term1 = if nr == 0 {
            // rint on an f64 ratio: half an lsb plus negligible
            // double-rounding slack.
            0.5 + 1e-6
        } else {
            error_bound_term1(p) // None => divergent subinterval
                .unwrap_or(f64::INFINITY)
        };
        if term1.is_finite() {
            static_max_ulp =
                Some((term1 + term2).max(sat_term) + 1e-6);
        }
        push(
            &mut obs,
            "error_bound_finite",
            term1.is_finite(),
            format!(
                "term1 (back end) = {term1:.3}, term2 (chain) = {term2:.3}, saturation = {sat_term:.3} lsb"
            ),
        );
    }

    VerifyReport {
        config: *cfg,
        obligations: obs,
        simd_obligations: simd,
        stages,
        simd_admitted,
        simd_provable,
        nr_residual,
        static_max_ulp,
    }
}

/// Back-end error bound (nr >= 1): max over ~1024 `f`-subintervals of
/// the closed-form `|V - r(f^)|` bound (see module docs), plus the
/// final recompose rounding.
fn error_bound_term1(p: &DatapathParams) -> Option<f64> {
    let cfg = &p.cfg;
    let l = cfg.lut_bits;
    let m = cfg.mult_bits;
    let out = cfg.out_frac;
    let s_d = l + 1 - m;
    let full = 1i128 << l;
    let two_m = 2f64.powi(m as i32);
    let s_f = p.seed_const as f64 / two_m;
    let pow_out = 2f64.powi(out as i32);
    let tau = (1i128 << s_d) as f64 - 1.0;
    let kdiv = 1024i128.min(full);
    let mut worst = 0f64;
    for k in 0..kdiv {
        let fa = full * k / kdiv;
        let fb = full * (k + 1) / kdiv;
        let da = ((full + fa) >> s_d) as f64;
        let db = ((full + fb) >> s_d) as f64;
        let mut eps = seed_residual(s_f, da / two_m, db / two_m);
        for _ in 0..cfg.nr_stages {
            eps = residual_step(eps, m);
        }
        if eps >= 1.0 {
            return None;
        }
        let num_hi = (full - fa) as f64;
        let a_lo = (((full + fa) >> s_d) << s_d) as f64;
        let den_lo = (full + fa) as f64;
        let mut sub =
            pow_out * num_hi * (eps / a_lo + tau / (a_lo * den_lo));
        if cfg.subtractor == Subtractor::Ones {
            sub += pow_out * (1.0 + eps) / a_lo;
        }
        worst = worst.max(sub);
    }
    Some(worst * (1.0 + 1e-9) + 0.5)
}
