//! Velocity-factor LUT construction (paper eq. 7–9 and Table I).
//!
//! `entry[mask] = round(2^L * Π_{j: mask_j=1} e^(-2 · 2^(p_j - in_frac)))`
//!
//! The product over a group's set bits is evaluated exactly in f64 and
//! rounded once — that is what a synthesized ROM stores. Matches
//! `TanhConfig.lut_tables()` in the python spec bit-for-bit (enforced by
//! the golden-vector tests).

use super::config::TanhConfig;

/// Build the grouped LUT tables; one `Vec` (of `2^|group|` entries) per
/// group, entries as u0.L words in `(0, 2^L]`.
pub fn lut_tables(cfg: &TanhConfig) -> Vec<Vec<i64>> {
    let one = 1i64 << cfg.lut_bits;
    cfg.group_positions()
        .iter()
        .map(|positions| {
            (0..1usize << positions.len())
                .map(|mask| {
                    let a: f64 = positions
                        .iter()
                        .enumerate()
                        .filter(|(j, _)| (mask >> j) & 1 == 1)
                        .map(|(_, &p)| (p as f64 - cfg.in_frac as f64).exp2())
                        .sum();
                    let v = (one as f64 * (-2.0 * a).exp()).round_ties_even()
                        as i64;
                    v.min(one)
                })
                .collect()
        })
        .collect()
}

/// The velocity factor for a single place value `2^(p - in_frac)`,
/// as stored by the per-bit ("registers") variant of fig. 3.
pub fn single_bit_factor(cfg: &TanhConfig, p: u32) -> i64 {
    let one = 1i64 << cfg.lut_bits;
    let a = (p as f64 - cfg.in_frac as f64).exp2();
    ((one as f64 * (-2.0 * a).exp()).round_ties_even() as i64).min(one)
}

/// Render the paper's Table I (2-bit grouped LUT) for documentation /
/// the `table1_lut` bench.
pub fn table1_rows(cfg: &TanhConfig) -> Vec<(String, i64, f64)> {
    let mut cfg2 = *cfg;
    cfg2.lut_group = 2;
    cfg2.shuffle = false;
    let tables = lut_tables(&cfg2);
    let positions = cfg2.group_positions();
    let mut rows = Vec::new();
    for (g, (pos, table)) in positions.iter().zip(&tables).enumerate() {
        for (mask, &v) in table.iter().enumerate() {
            let bits = format!("{mask:0width$b}", width = pos.len());
            rows.push((
                format!("LUT{g}[{bits}] (bits {:?})", pos),
                v,
                v as f64 / (1i64 << cfg2.lut_bits) as f64,
            ));
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_zero_is_unity() {
        let cfg = TanhConfig::s3_12();
        for t in lut_tables(&cfg) {
            assert_eq!(t[0], 1i64 << cfg.lut_bits);
        }
    }

    #[test]
    fn entries_in_unit_interval() {
        // f = e^-2a in (0, 1]: the paper's §IV.B.2 scalability property.
        let cfg = TanhConfig::s3_12();
        for t in lut_tables(&cfg) {
            for &v in &t {
                assert!(v > 0 && v <= 1i64 << cfg.lut_bits);
            }
        }
    }

    #[test]
    fn multi_bit_entry_is_rounded_product() {
        // Table I: entry(11) ~= entry(01) * entry(10) (exact product, one
        // rounding — so within 2 ulp of the chained product).
        let cfg = TanhConfig::s3_12();
        let one = 1i64 << cfg.lut_bits;
        for t in lut_tables(&cfg) {
            if t.len() >= 4 {
                let approx = (t[1] as f64) * (t[2] as f64) / one as f64;
                assert!((t[3] as f64 - approx).abs() <= 2.0);
            }
        }
    }

    #[test]
    fn table_sizes_16bit() {
        let sizes: Vec<usize> =
            lut_tables(&TanhConfig::s3_12()).iter().map(Vec::len).collect();
        assert_eq!(sizes, vec![16, 16, 16, 8]);
    }

    #[test]
    fn single_bit_matches_group_entry() {
        let cfg = TanhConfig::s3_12().with_group(1);
        let tables = lut_tables(&cfg);
        for (g, pos) in cfg.group_positions().iter().enumerate() {
            assert_eq!(tables[g][1], single_bit_factor(&cfg, pos[0]));
        }
    }

    #[test]
    fn table1_rows_cover_all_masks() {
        let rows = table1_rows(&TanhConfig::s3_12());
        // 15 bits in groups of 2 -> 7 groups of 4 entries + 1 group of 2.
        assert_eq!(rows.len(), 7 * 4 + 2);
    }
}
