//! The paper's contribution: velocity-factor tanh datapath.
//!
//! * [`config`]   — static datapath parameters (mirrors
//!   `python/compile/kernels/config.py`, the cross-layer spec).
//! * [`lut`]      — grouped velocity-factor LUT construction (Table I).
//! * [`newton`]   — Newton-Raphson reciprocal (fig. 4).
//! * [`golden`]   — straight-line bit-accurate model (the spec oracle).
//! * [`unit`]     — precomputed, optimized implementation for serving.
//! * [`published`]— the unmodified Doerfler-style method of fig. 3
//!   (per-bit registers + eq. 3 residual compensation), kept as the
//!   ablation baseline that §IV.B.1 improves upon.
//! * [`simd`]     — runtime-selected AVX2 batch kernels (bit-exact,
//!   `TANHVF_SIMD` selectable) behind the `eval_batch_*` APIs.

pub mod config;
pub mod golden;
pub mod lut;
pub mod newton;
pub mod published;
pub mod sigmoid;
pub mod simd;
pub mod unit;

pub use config::{Subtractor, TanhConfig};
pub use golden::{tanh_golden, tanh_golden_batch};
pub use sigmoid::{ExpUnit, SigmoidUnit};
pub use simd::SimdMode;
pub use unit::TanhUnit;
