//! `TanhUnit` — the optimized, reusable implementation of the datapath
//! for the serving hot path.
//!
//! Output-identical to [`super::golden`] (property-tested + verified
//! exhaustively for the 16-bit point), but engineered for throughput:
//! prebuilt flat tables, precomputed group shifts, branch-light inner
//! loop, and an optional fully-tabulated mode (`precompute_all`) that
//! memoizes the entire input domain — the software analogue of taping
//! out the unit.

use super::config::{Subtractor, TanhConfig};
use super::lut::lut_tables;
use super::simd::{self, SimdMode};

/// Precomputed per-group addressing: the bit positions each address bit
/// gathers from, flattened for cache-friendly iteration.
#[derive(Clone, Debug)]
pub(crate) struct Group {
    /// `positions[j]` = input bit feeding address bit `j`.
    pub(crate) positions: Vec<u32>,
    /// Offset of this group's table in the flat `tables` vec.
    pub(crate) offset: usize,
}

/// A ready-to-serve tanh unit instance.
#[derive(Clone, Debug)]
pub struct TanhUnit {
    cfg: TanhConfig,
    pub(crate) groups: Vec<Group>,
    /// All group tables, flattened.
    pub(crate) tables: Vec<i64>,
    pub(crate) sat_threshold: i64,
    pub(crate) out_max: i64,
    /// Optional full-domain memo (index = input word - min_word).
    full_table: Option<Vec<i32>>,
}

impl TanhUnit {
    /// Build the unit (tables + addressing) for `cfg`.
    pub fn new(cfg: TanhConfig) -> Result<TanhUnit, String> {
        cfg.validate()?;
        // Every constructed unit must pass the static datapath verifier
        // (overflow-freedom, shift validity, saturation coverage, SIMD
        // gate soundness). validate() is the format-level check; this is
        // the semantic one. Debug-only: the check is O(groups + nr) but
        // construction sits on the serving path for lazy routes.
        #[cfg(debug_assertions)]
        if let Err(e) = crate::analysis::verify::verify_safety(&cfg) {
            panic!("{e}");
        }
        let mut tables = Vec::new();
        let mut groups = Vec::new();
        for (positions, table) in
            cfg.group_positions().into_iter().zip(lut_tables(&cfg))
        {
            groups.push(Group { positions, offset: tables.len() });
            tables.extend(table);
        }
        Ok(TanhUnit {
            sat_threshold: cfg.sat_threshold(),
            out_max: cfg.out_max(),
            cfg,
            groups,
            tables,
            full_table: None,
        })
    }

    pub fn config(&self) -> &TanhConfig {
        &self.cfg
    }

    /// Memoize the whole input domain (2^in_width words). For the 16-bit
    /// point this is a 256 KiB table — the fastest possible software
    /// implementation and the shape a ROM-compiler would produce.
    pub fn precompute_all(&mut self) {
        let w = self.cfg.in_width();
        let lo = -(1i64 << (w - 1));
        let hi = 1i64 << (w - 1);
        let table: Vec<i32> =
            (lo..hi).map(|x| self.eval_datapath(x) as i32).collect();
        self.full_table = Some(table);
    }

    /// Evaluate one word (dispatches to the memo if built).
    #[inline]
    pub fn eval(&self, x: i64) -> i64 {
        if let Some(t) = &self.full_table {
            let lo = -(1i64 << (self.cfg.in_width() - 1));
            return t[(x - lo) as usize] as i64;
        }
        self.eval_datapath(x)
    }

    /// Evaluate one word through the live datapath.
    #[inline]
    pub fn eval_datapath(&self, x: i64) -> i64 {
        let neg = x < 0;
        let n = x.unsigned_abs() as i64;

        if n >= self.sat_threshold {
            return if neg { -self.out_max } else { self.out_max };
        }

        let cfg = &self.cfg;
        let l = cfg.lut_bits;
        let one_l = 1i64 << l;
        let half_l = 1i64 << (l - 1);

        // LUT product chain.
        let g0 = &self.groups[0];
        let mut f = unsafe {
            *self.tables.get_unchecked(g0.offset + gather(n, &g0.positions))
        };
        for g in &self.groups[1..] {
            let e = unsafe {
                *self.tables.get_unchecked(g.offset + gather(n, &g.positions))
            };
            f = (f * e + half_l) >> l;
        }

        // Output stage.
        let num = match cfg.subtractor {
            Subtractor::Twos => one_l - f,
            Subtractor::Ones => (one_l - 1) - f,
        };
        let den = one_l + f;

        let t = if cfg.nr_stages == 0 {
            crate::fixed::rint(
                num as f64 / den as f64 * (1i64 << cfg.out_frac) as f64,
            )
        } else {
            let m = cfg.mult_bits;
            let half_m = 1i64 << (m - 1);
            let two_m = 2i64 << m;
            let d = den >> (l + 1 - m);
            let mut xr = cfg.nr_seed_const() - (d << 1);
            // Specialized 3-stage unroll (the production configuration):
            // lets the compiler keep d/xr in registers with no loop
            // carried branch (§Perf iteration 2 in EXPERIMENTS.md).
            if cfg.nr_stages == 3 {
                let t0 = (d * xr + half_m) >> m;
                xr = (xr * (two_m - t0) + half_m) >> m;
                let t1 = (d * xr + half_m) >> m;
                xr = (xr * (two_m - t1) + half_m) >> m;
                let t2 = (d * xr + half_m) >> m;
                xr = (xr * (two_m - t2) + half_m) >> m;
            } else {
                for _ in 0..cfg.nr_stages {
                    let t0 = (d * xr + half_m) >> m;
                    xr = (xr * (two_m - t0) + half_m) >> m;
                }
            }
            let shift = l + m + 1 - cfg.out_frac;
            (num * xr + (1i64 << (shift - 1))) >> shift
        };

        let t = t.clamp(0, self.out_max);
        if neg {
            -t
        } else {
            t
        }
    }

    /// Batch evaluation into a caller-provided buffer. Dispatches to
    /// the process-wide SIMD mode (see [`super::simd`]); every mode is
    /// bit-exact.
    pub fn eval_batch_into(&self, xs: &[i64], out: &mut [i64]) {
        self.eval_batch_mode(simd::active(), xs, out);
    }

    /// Batch evaluation pinned to an explicit mode (bench/test hook).
    /// `Avx2` degrades to the scalar loop when the host lacks the
    /// feature or the config is outside the vectorizable envelope, so
    /// it is always safe to request.
    pub fn eval_batch_mode(
        &self,
        mode: SimdMode,
        xs: &[i64],
        out: &mut [i64],
    ) {
        assert_eq!(xs.len(), out.len());
        match mode {
            SimdMode::Off => {
                for (o, &x) in out.iter_mut().zip(xs) {
                    *o = self.eval(x);
                }
            }
            SimdMode::Scalar => self.eval_batch_scalar(xs, out),
            SimdMode::Avx2 => self.eval_batch_avx2(xs, out),
        }
    }

    /// The portable batch loops (memo lookup hoisted / datapath).
    fn eval_batch_scalar(&self, xs: &[i64], out: &mut [i64]) {
        if let Some(t) = &self.full_table {
            let lo = -(1i64 << (self.cfg.in_width() - 1));
            for (o, &x) in out.iter_mut().zip(xs) {
                *o = t[(x - lo) as usize] as i64;
            }
        } else {
            for (o, &x) in out.iter_mut().zip(xs) {
                *o = self.eval_datapath(x);
            }
        }
    }

    /// AVX2 batch: memo gather when the memo is built (and every word
    /// is in-domain — an out-of-domain word falls back to the scalar
    /// loop so the panic site stays identical), else the vectorized
    /// datapath when the config qualifies, else scalar.
    fn eval_batch_avx2(&self, xs: &[i64], out: &mut [i64]) {
        #[cfg(target_arch = "x86_64")]
        {
            if simd::avx2_supported() {
                if let Some(t) = &self.full_table {
                    let lo = -(1i64 << (self.cfg.in_width() - 1));
                    let len = t.len() as u64;
                    if xs.iter().all(|&x| (x.wrapping_sub(lo) as u64) < len)
                    {
                        // SAFETY: avx2 checked; indices pre-validated.
                        unsafe { simd::x86::gather_memo_i64(t, lo, xs, out) };
                        return;
                    }
                } else if simd::datapath_eligible(&self.cfg) {
                    // SAFETY: avx2 checked; config eligible.
                    unsafe { simd::x86::datapath_avx2(self, xs, out) };
                    return;
                }
            }
        }
        self.eval_batch_scalar(xs, out);
    }

    pub fn eval_batch(&self, xs: &[i64]) -> Vec<i64> {
        let mut out = vec![0i64; xs.len()];
        self.eval_batch_into(xs, &mut out);
        out
    }

    /// In-place batch evaluation (stages through a stack buffer so the
    /// vector kernels keep disjoint load/store slices).
    pub fn eval_batch_in_place(&self, buf: &mut [i64]) {
        let mut tmp = [0i64; 256];
        let mut i = 0;
        while i < buf.len() {
            let k = (buf.len() - i).min(256);
            tmp[..k].copy_from_slice(&buf[i..i + k]);
            self.eval_batch_into(&tmp[..k], &mut buf[i..i + k]);
            i += k;
        }
    }

    /// i32-word batch API (the PJRT artifact I/O type).
    pub fn eval_batch_i32(&self, xs: &[i32]) -> Vec<i32> {
        let mut out = vec![0i32; xs.len()];
        self.eval_batch_i32_into(xs, &mut out);
        out
    }

    /// i32-word batch into a caller buffer. With the memo built and
    /// AVX2 active this is a direct 8-lane gather; otherwise it stages
    /// through the i64 batch path in stack-sized chunks (which is how
    /// it picks up the memo/datapath fast paths it used to bypass).
    pub fn eval_batch_i32_into(&self, xs: &[i32], out: &mut [i32]) {
        assert_eq!(xs.len(), out.len());
        #[cfg(target_arch = "x86_64")]
        {
            if simd::active() == SimdMode::Avx2 && simd::avx2_supported() {
                if let Some(t) = &self.full_table {
                    let w = self.cfg.in_width();
                    if w <= 31 {
                        let bias = 1i32 << (w - 1);
                        let len = t.len() as u32;
                        if xs
                            .iter()
                            .all(|&x| (x.wrapping_add(bias) as u32) < len)
                        {
                            // SAFETY: avx2 checked; indices validated.
                            unsafe {
                                simd::x86::gather_memo_i32(t, bias, xs, out)
                            };
                            return;
                        }
                    }
                }
            }
        }
        let mut xbuf = [0i64; 256];
        let mut obuf = [0i64; 256];
        for (xc, oc) in xs.chunks(256).zip(out.chunks_mut(256)) {
            let k = xc.len();
            for (b, &x) in xbuf[..k].iter_mut().zip(xc) {
                *b = x as i64;
            }
            self.eval_batch_into(&xbuf[..k], &mut obuf[..k]);
            for (o, &b) in oc.iter_mut().zip(&obuf[..k]) {
                *o = b as i32;
            }
        }
    }

    /// Float convenience: quantize -> datapath -> dequantize.
    pub fn eval_f64(&self, x: f64) -> f64 {
        let w = self.cfg.in_format().quantize(x, crate::fixed::Round::Nearest);
        self.cfg.out_format().dequantize(self.eval(w))
    }

    /// Sigmoid through the same unit: sigma(x) = (1 + tanh(x/2)) / 2.
    pub fn sigmoid_f64(&self, x: f64) -> f64 {
        (1.0 + self.eval_f64(x * 0.5)) * 0.5
    }
}

/// Gather the address bits for one LUT group.
#[inline(always)]
fn gather(n: i64, positions: &[u32]) -> usize {
    let mut addr = 0usize;
    for (j, &p) in positions.iter().enumerate() {
        addr |= (((n >> p) & 1) as usize) << j;
    }
    addr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{self, int};
    use crate::tanh::golden::tanh_golden_batch;

    #[test]
    fn matches_golden_16bit_sampled() {
        let cfg = TanhConfig::s3_12();
        let unit = TanhUnit::new(cfg).unwrap();
        let xs: Vec<i64> = (-32768..32768).step_by(13).collect();
        let want = tanh_golden_batch(&xs, &cfg);
        let got = unit.eval_batch(&xs);
        assert_eq!(got, want);
    }

    #[test]
    fn matches_golden_8bit_exhaustive() {
        let cfg = TanhConfig::s3_5();
        let unit = TanhUnit::new(cfg).unwrap();
        let xs: Vec<i64> = (-256..256).collect();
        assert_eq!(unit.eval_batch(&xs), tanh_golden_batch(&xs, &cfg));
    }

    #[test]
    fn memo_is_output_identical() {
        let cfg = TanhConfig::s3_12();
        let mut unit = TanhUnit::new(cfg).unwrap();
        let xs: Vec<i64> = (-32768..32768).step_by(7).collect();
        let live = unit.eval_batch(&xs);
        unit.precompute_all();
        assert_eq!(unit.eval_batch(&xs), live);
    }

    #[test]
    fn property_unit_equals_golden() {
        let cfg = TanhConfig::s3_12().with_nr(2).with_subtractor(Subtractor::Ones);
        let unit = TanhUnit::new(cfg).unwrap();
        let g = int(-32768, 32767);
        proptest::assert_prop("unit==golden", 42, 2000, &g, |&x| {
            let got = unit.eval(x);
            let want = crate::tanh::golden::tanh_golden(x, &cfg);
            if got == want {
                Ok(())
            } else {
                Err(format!("x={x}: unit {got} != golden {want}"))
            }
        });
    }

    #[test]
    fn f64_api_accuracy() {
        let unit = TanhUnit::new(TanhConfig::s3_12()).unwrap();
        for i in -40..=40 {
            let x = i as f64 * 0.1;
            assert!((unit.eval_f64(x) - x.tanh()).abs() < 2e-4, "x={x}");
        }
    }

    #[test]
    fn sigmoid_accuracy() {
        let unit = TanhUnit::new(TanhConfig::s3_12()).unwrap();
        for i in -30..=30 {
            let x = i as f64 * 0.25;
            let want = 1.0 / (1.0 + (-x).exp());
            assert!((unit.sigmoid_f64(x) - want).abs() < 2e-4, "x={x}");
        }
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = TanhConfig::s3_12();
        cfg.lut_group = 0;
        assert!(TanhUnit::new(cfg).is_err());
    }
}
