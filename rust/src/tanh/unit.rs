//! `TanhUnit` — the optimized, reusable implementation of the datapath
//! for the serving hot path.
//!
//! Output-identical to [`super::golden`] (property-tested + verified
//! exhaustively for the 16-bit point), but engineered for throughput:
//! prebuilt flat tables, precomputed group shifts, branch-light inner
//! loop, and an optional fully-tabulated mode (`precompute_all`) that
//! memoizes the entire input domain — the software analogue of taping
//! out the unit.

use super::config::{Subtractor, TanhConfig};
use super::lut::lut_tables;

/// Precomputed per-group addressing: the bit positions each address bit
/// gathers from, flattened for cache-friendly iteration.
#[derive(Clone, Debug)]
struct Group {
    /// `positions[j]` = input bit feeding address bit `j`.
    positions: Vec<u32>,
    /// Offset of this group's table in the flat `tables` vec.
    offset: usize,
}

/// A ready-to-serve tanh unit instance.
#[derive(Clone, Debug)]
pub struct TanhUnit {
    cfg: TanhConfig,
    groups: Vec<Group>,
    /// All group tables, flattened.
    tables: Vec<i64>,
    sat_threshold: i64,
    out_max: i64,
    /// Optional full-domain memo (index = input word - min_word).
    full_table: Option<Vec<i32>>,
}

impl TanhUnit {
    /// Build the unit (tables + addressing) for `cfg`.
    pub fn new(cfg: TanhConfig) -> Result<TanhUnit, String> {
        cfg.validate()?;
        let mut tables = Vec::new();
        let mut groups = Vec::new();
        for (positions, table) in
            cfg.group_positions().into_iter().zip(lut_tables(&cfg))
        {
            groups.push(Group { positions, offset: tables.len() });
            tables.extend(table);
        }
        Ok(TanhUnit {
            sat_threshold: cfg.sat_threshold(),
            out_max: cfg.out_max(),
            cfg,
            groups,
            tables,
            full_table: None,
        })
    }

    pub fn config(&self) -> &TanhConfig {
        &self.cfg
    }

    /// Memoize the whole input domain (2^in_width words). For the 16-bit
    /// point this is a 256 KiB table — the fastest possible software
    /// implementation and the shape a ROM-compiler would produce.
    pub fn precompute_all(&mut self) {
        let w = self.cfg.in_width();
        let lo = -(1i64 << (w - 1));
        let hi = 1i64 << (w - 1);
        let table: Vec<i32> =
            (lo..hi).map(|x| self.eval_datapath(x) as i32).collect();
        self.full_table = Some(table);
    }

    /// Evaluate one word (dispatches to the memo if built).
    #[inline]
    pub fn eval(&self, x: i64) -> i64 {
        if let Some(t) = &self.full_table {
            let lo = -(1i64 << (self.cfg.in_width() - 1));
            return t[(x - lo) as usize] as i64;
        }
        self.eval_datapath(x)
    }

    /// Evaluate one word through the live datapath.
    #[inline]
    pub fn eval_datapath(&self, x: i64) -> i64 {
        let neg = x < 0;
        let n = x.unsigned_abs() as i64;

        if n >= self.sat_threshold {
            return if neg { -self.out_max } else { self.out_max };
        }

        let cfg = &self.cfg;
        let l = cfg.lut_bits;
        let one_l = 1i64 << l;
        let half_l = 1i64 << (l - 1);

        // LUT product chain.
        let g0 = &self.groups[0];
        let mut f = unsafe {
            *self.tables.get_unchecked(g0.offset + gather(n, &g0.positions))
        };
        for g in &self.groups[1..] {
            let e = unsafe {
                *self.tables.get_unchecked(g.offset + gather(n, &g.positions))
            };
            f = (f * e + half_l) >> l;
        }

        // Output stage.
        let num = match cfg.subtractor {
            Subtractor::Twos => one_l - f,
            Subtractor::Ones => (one_l - 1) - f,
        };
        let den = one_l + f;

        let t = if cfg.nr_stages == 0 {
            crate::fixed::rint(
                num as f64 / den as f64 * (1i64 << cfg.out_frac) as f64,
            )
        } else {
            let m = cfg.mult_bits;
            let half_m = 1i64 << (m - 1);
            let two_m = 2i64 << m;
            let d = den >> (l + 1 - m);
            let mut xr = cfg.nr_seed_const() - (d << 1);
            // Specialized 3-stage unroll (the production configuration):
            // lets the compiler keep d/xr in registers with no loop
            // carried branch (§Perf iteration 2 in EXPERIMENTS.md).
            if cfg.nr_stages == 3 {
                let t0 = (d * xr + half_m) >> m;
                xr = (xr * (two_m - t0) + half_m) >> m;
                let t1 = (d * xr + half_m) >> m;
                xr = (xr * (two_m - t1) + half_m) >> m;
                let t2 = (d * xr + half_m) >> m;
                xr = (xr * (two_m - t2) + half_m) >> m;
            } else {
                for _ in 0..cfg.nr_stages {
                    let t0 = (d * xr + half_m) >> m;
                    xr = (xr * (two_m - t0) + half_m) >> m;
                }
            }
            let shift = l + m + 1 - cfg.out_frac;
            (num * xr + (1i64 << (shift - 1))) >> shift
        };

        let t = t.clamp(0, self.out_max);
        if neg {
            -t
        } else {
            t
        }
    }

    /// Batch evaluation into a caller-provided buffer.
    pub fn eval_batch_into(&self, xs: &[i64], out: &mut [i64]) {
        assert_eq!(xs.len(), out.len());
        if let Some(t) = &self.full_table {
            let lo = -(1i64 << (self.cfg.in_width() - 1));
            for (o, &x) in out.iter_mut().zip(xs) {
                *o = t[(x - lo) as usize] as i64;
            }
        } else {
            for (o, &x) in out.iter_mut().zip(xs) {
                *o = self.eval_datapath(x);
            }
        }
    }

    pub fn eval_batch(&self, xs: &[i64]) -> Vec<i64> {
        let mut out = vec![0i64; xs.len()];
        self.eval_batch_into(xs, &mut out);
        out
    }

    /// i32-word batch API (the PJRT artifact I/O type).
    pub fn eval_batch_i32(&self, xs: &[i32]) -> Vec<i32> {
        xs.iter().map(|&x| self.eval(x as i64) as i32).collect()
    }

    /// Float convenience: quantize -> datapath -> dequantize.
    pub fn eval_f64(&self, x: f64) -> f64 {
        let w = self.cfg.in_format().quantize(x, crate::fixed::Round::Nearest);
        self.cfg.out_format().dequantize(self.eval(w))
    }

    /// Sigmoid through the same unit: sigma(x) = (1 + tanh(x/2)) / 2.
    pub fn sigmoid_f64(&self, x: f64) -> f64 {
        (1.0 + self.eval_f64(x * 0.5)) * 0.5
    }
}

/// Gather the address bits for one LUT group.
#[inline(always)]
fn gather(n: i64, positions: &[u32]) -> usize {
    let mut addr = 0usize;
    for (j, &p) in positions.iter().enumerate() {
        addr |= (((n >> p) & 1) as usize) << j;
    }
    addr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{self, int};
    use crate::tanh::golden::tanh_golden_batch;

    #[test]
    fn matches_golden_16bit_sampled() {
        let cfg = TanhConfig::s3_12();
        let unit = TanhUnit::new(cfg).unwrap();
        let xs: Vec<i64> = (-32768..32768).step_by(13).collect();
        let want = tanh_golden_batch(&xs, &cfg);
        let got = unit.eval_batch(&xs);
        assert_eq!(got, want);
    }

    #[test]
    fn matches_golden_8bit_exhaustive() {
        let cfg = TanhConfig::s3_5();
        let unit = TanhUnit::new(cfg).unwrap();
        let xs: Vec<i64> = (-256..256).collect();
        assert_eq!(unit.eval_batch(&xs), tanh_golden_batch(&xs, &cfg));
    }

    #[test]
    fn memo_is_output_identical() {
        let cfg = TanhConfig::s3_12();
        let mut unit = TanhUnit::new(cfg).unwrap();
        let xs: Vec<i64> = (-32768..32768).step_by(7).collect();
        let live = unit.eval_batch(&xs);
        unit.precompute_all();
        assert_eq!(unit.eval_batch(&xs), live);
    }

    #[test]
    fn property_unit_equals_golden() {
        let cfg = TanhConfig::s3_12().with_nr(2).with_subtractor(Subtractor::Ones);
        let unit = TanhUnit::new(cfg).unwrap();
        let g = int(-32768, 32767);
        proptest::assert_prop("unit==golden", 42, 2000, &g, |&x| {
            let got = unit.eval(x);
            let want = crate::tanh::golden::tanh_golden(x, &cfg);
            if got == want {
                Ok(())
            } else {
                Err(format!("x={x}: unit {got} != golden {want}"))
            }
        });
    }

    #[test]
    fn f64_api_accuracy() {
        let unit = TanhUnit::new(TanhConfig::s3_12()).unwrap();
        for i in -40..=40 {
            let x = i as f64 * 0.1;
            assert!((unit.eval_f64(x) - x.tanh()).abs() < 2e-4, "x={x}");
        }
    }

    #[test]
    fn sigmoid_accuracy() {
        let unit = TanhUnit::new(TanhConfig::s3_12()).unwrap();
        for i in -30..=30 {
            let x = i as f64 * 0.25;
            let want = 1.0 / (1.0 + (-x).exp());
            assert!((unit.sigmoid_f64(x) - want).abs() < 2e-4, "x={x}");
        }
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = TanhConfig::s3_12();
        cfg.lut_group = 0;
        assert!(TanhUnit::new(cfg).is_err());
    }
}
