//! The *published* (unimproved) method of paper fig. 3 — per-bit
//! velocity-factor registers above a threshold plus the eq. 3 small-angle
//! compensation for the residual low bits:
//!
//! `tanh(a + b) ≈ tanh(a) + b · (1 - tanh²(a))`   (eq. 3)
//!
//! Kept as an ablation baseline: §IV.B.1 shows the compensation both
//! introduces error and costs two extra last-stage multipliers, which the
//! optimized datapath (`golden`/`unit`) removes.

use crate::fixed::{rint, round_mul};

use super::config::{Subtractor, TanhConfig};
use super::lut::single_bit_factor;
use super::newton::nr_recip;

/// Configuration: the paper's example keeps registers for place values
/// `2^k`, `-7 <= k <= 2` (threshold `2^-7`) for the s3.12 format.
#[derive(Clone, Copy, Debug)]
pub struct PublishedConfig {
    pub base: TanhConfig,
    /// Keep per-bit registers for place values `>= 2^-threshold_exp`.
    pub threshold_exp: i32,
}

impl Default for PublishedConfig {
    fn default() -> Self {
        PublishedConfig { base: TanhConfig::s3_12(), threshold_exp: 7 }
    }
}

impl PublishedConfig {
    /// Bit positions (of the magnitude word) held in registers.
    pub fn register_positions(&self) -> Vec<u32> {
        let cfg = &self.base;
        (0..cfg.mag_bits())
            .filter(|&p| p as i32 - cfg.in_frac as i32 >= -self.threshold_exp)
            .collect()
    }

    /// Number of velocity-factor registers (paper: 10 for s3.12, t=7).
    pub fn register_count(&self) -> usize {
        self.register_positions().len()
    }
}

/// Evaluate one word via the published method.
pub fn tanh_published(x: i64, pc: &PublishedConfig) -> i64 {
    let cfg = &pc.base;
    let sign = x < 0;
    let n = x.unsigned_abs() as i64;
    let one_l = 1i64 << cfg.lut_bits;

    if n >= cfg.sat_threshold() {
        let t = cfg.out_max();
        return if sign { -t } else { t };
    }

    // Product over per-bit registers (high bits only).
    let mut f = one_l;
    for &p in &pc.register_positions() {
        if (n >> p) & 1 == 1 {
            f = round_mul(f, single_bit_factor(cfg, p), cfg.lut_bits);
        }
    }

    // tanh(a) = (1 - f)/(1 + f) through the same divider as the main path.
    let num = match cfg.subtractor {
        Subtractor::Twos => one_l - f,
        Subtractor::Ones => (one_l - 1) - f,
    };
    let den = one_l + f;
    let tanh_a: i64 = if cfg.nr_stages == 0 {
        rint(num as f64 / den as f64 * (1i64 << cfg.out_frac) as f64)
    } else {
        let d = den >> (cfg.lut_bits + 1 - cfg.mult_bits);
        let recip = nr_recip(d, cfg);
        let shift = cfg.lut_bits + cfg.mult_bits + 1 - cfg.out_frac;
        (num * recip + (1i64 << (shift - 1))) >> shift
    };

    // Residual low bits b (value < 2^-threshold_exp) via eq. 3:
    // tanh(a+b) = tanh(a) + b * (1 - tanh^2 a). Two extra multipliers.
    let low_mask = (1i64 << (cfg.in_frac as i32 - pc.threshold_exp)) - 1;
    let b = n & low_mask; // b as s{in} word
    let t = if b != 0 {
        let q = cfg.out_frac;
        // tanh_a is u0.q; tanh^2 a at q frac bits.
        let t2 = round_mul(tanh_a, tanh_a, q);
        let comp_factor = (1i64 << q) - t2; // 1 - tanh^2 a, u0.q
        // b is at in_frac bits; product at q + in_frac, renormalize to q.
        let comp = (b * comp_factor + (1i64 << (cfg.in_frac - 1)))
            >> cfg.in_frac;
        tanh_a + comp
    } else {
        tanh_a
    };

    let t = t.clamp(0, cfg.out_max());
    if sign {
        -t
    } else {
        t
    }
}

/// Exhaustive max |error| vs f64 tanh (for the ablation bench).
pub fn published_max_error(pc: &PublishedConfig) -> f64 {
    let cfg = &pc.base;
    let half = 1i64 << cfg.mag_bits();
    let inf = cfg.in_format();
    let outf = cfg.out_format();
    let mut worst = 0.0f64;
    for x in -half..half {
        let got = outf.dequantize(tanh_published(x, pc));
        let want = inf.dequantize(x).tanh();
        worst = worst.max((got - want).abs());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tanh::golden::tanh_golden;

    #[test]
    fn register_count_matches_paper() {
        // Paper §IV.A: "10 registers ... for 2^k (-7 <= k <= 2)" for s3.12.
        let pc = PublishedConfig::default();
        assert_eq!(pc.register_count(), 10);
        // positions are the top 10 magnitude bits (5..14)
        assert_eq!(pc.register_positions(), (5..15).collect::<Vec<_>>());
    }

    #[test]
    fn agrees_with_golden_when_no_residual() {
        // Inputs with only register bits set take the identical path
        // (modulo grouped-vs-per-bit rounding, <= 2 lsb).
        let pc = PublishedConfig::default();
        let g1 = pc.base.with_group(1);
        for x in [0i64, 1 << 5, 1 << 10, (1 << 12) + (1 << 7), 3 << 11] {
            let a = tanh_published(x, &pc);
            let b = tanh_golden(x, &g1);
            assert!((a - b).abs() <= 2, "x={x}: {a} vs {b}");
        }
    }

    #[test]
    fn residual_compensation_beats_truncation() {
        // eq. 3 must be better than ignoring the low bits entirely.
        let pc = PublishedConfig::default();
        let cfg = &pc.base;
        let x = (1i64 << 9) + 37; // high bit + low residual
        let t_comp = cfg.out_format().dequantize(tanh_published(x, &pc));
        let t_trunc = cfg
            .out_format()
            .dequantize(tanh_published(x & !0x1f, &pc));
        let want = cfg.in_format().dequantize(x).tanh();
        assert!((t_comp - want).abs() < (t_trunc - want).abs());
    }

    #[test]
    fn worse_than_optimized_method() {
        // §IV.B.1's motivation: the optimized datapath beats the
        // published method's max error (sampled here; exhaustive in the
        // ablation bench).
        let pc = PublishedConfig::default();
        let cfg = pc.base;
        let mut worst_pub = 0.0f64;
        let mut worst_opt = 0.0f64;
        let inf = cfg.in_format();
        let outf = cfg.out_format();
        for x in (-32768i64..32768).step_by(11) {
            let want = inf.dequantize(x).tanh();
            worst_pub = worst_pub
                .max((outf.dequantize(tanh_published(x, &pc)) - want).abs());
            worst_opt = worst_opt
                .max((outf.dequantize(tanh_golden(x, &cfg)) - want).abs());
        }
        assert!(worst_pub > worst_opt, "pub {worst_pub} opt {worst_opt}");
    }

    #[test]
    fn odd_symmetry() {
        let pc = PublishedConfig::default();
        for x in [3i64, 100, 5000, 20000] {
            assert_eq!(tanh_published(x, &pc), -tanh_published(-x, &pc));
        }
    }
}
