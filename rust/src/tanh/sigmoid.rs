//! Sigmoid and exp(-2x) units derived from the same velocity-factor
//! datapath — the paper's "free" extensions.
//!
//! * **Sigmoid**: `sigma(x) = (1 + tanh(x/2)) / 2`. In hardware the
//!   halving is a 1-bit pre-shift of the input word and the final
//!   `(1+t)/2` is a concat + shift: the tanh core is reused unchanged.
//!   Every §II baseline paper ("tanh sigmoid function") implements this
//!   pair; here it is one unit.
//! * **Exp**: the velocity factor itself *is* `e^(-2a)` (eq. 9), so the
//!   LUT product chain with no output stage at all yields a hardware
//!   `exp(-2x)` for x >= 0 — reference [10]'s broader "fast exponential"
//!   claim realized on the same silicon.

use super::config::TanhConfig;
use super::lut::lut_tables;
use super::unit::TanhUnit;
use crate::fixed::{round_mul, QFormat};

/// Sigmoid unit: wraps the tanh core with the shift trick.
pub struct SigmoidUnit {
    tanh: TanhUnit,
}

impl SigmoidUnit {
    pub fn new(cfg: TanhConfig) -> Result<SigmoidUnit, String> {
        // The (1 + t) >> 1 recombination needs at least one output
        // fraction bit, and the float mapping scales by 2^out_frac —
        // reject degenerate formats here so no out_frac-dependent shift
        // downstream can underflow.
        if cfg.out_frac < 1 {
            return Err(format!(
                "sigmoid needs out_frac >= 1, got {}",
                cfg.out_frac
            ));
        }
        // The wrapped tanh core must pass the static datapath verifier
        // (TanhUnit::new repeats this; asserting here names the sigmoid
        // route in the failure, not the inner unit).
        #[cfg(debug_assertions)]
        if cfg.validate().is_ok() {
            if let Err(e) = crate::analysis::verify::verify_safety(&cfg) {
                panic!("{e}");
            }
        }
        Ok(SigmoidUnit { tanh: TanhUnit::new(cfg)? })
    }

    pub fn config(&self) -> &TanhConfig {
        self.tanh.config()
    }

    /// Word-level sigmoid: input s{in_int}.{in_frac} word, output
    /// u0.{out_frac} word in [0, 2^out_frac] representing [0, 1].
    ///
    /// Hardware: arithmetic-shift the input right by 1 (x/2, rounding
    /// toward -inf like the wire does), tanh core, then (1 + t) >> 1
    /// with the lsb of (1+t) kept by widening the output to out_frac.
    pub fn eval(&self, x: i64) -> i64 {
        // Rounding pre-shift (x/2 to nearest, ties away from zero):
        // one half-adder on the magnitude in hardware — the sign split
        // already exists at the tanh core input, so rounding the
        // magnitude keeps sigma(-x) = 1 - sigma(x) exact.
        let half = if x >= 0 { (x + 1) >> 1 } else { -((1 - x) >> 1) };
        let t = self.tanh.eval(half);
        let one = 1i64 << self.tanh.config().out_frac;
        (one + t) >> 1
    }

    /// Batch sigmoid into a caller buffer: the rounding pre-shift and
    /// the `(1 + t) >> 1` recombination are cheap linear passes; the
    /// tanh core between them runs the batch (SIMD-dispatched) path.
    /// Bit-exact vs per-word [`Self::eval`].
    pub fn eval_batch_into(&self, xs: &[i64], out: &mut [i64]) {
        assert_eq!(xs.len(), out.len());
        for (o, &x) in out.iter_mut().zip(xs) {
            *o = if x >= 0 { (x + 1) >> 1 } else { -((1 - x) >> 1) };
        }
        self.tanh.eval_batch_in_place(out);
        let one = 1i64 << self.tanh.config().out_frac;
        for o in out.iter_mut() {
            *o = (one + *o) >> 1;
        }
    }

    pub fn eval_batch(&self, xs: &[i64]) -> Vec<i64> {
        let mut out = vec![0i64; xs.len()];
        self.eval_batch_into(xs, &mut out);
        out
    }

    /// Float convenience.
    pub fn eval_f64(&self, x: f64) -> f64 {
        let cfg = self.tanh.config();
        let w = cfg.in_format().quantize(x, crate::fixed::Round::Nearest);
        // Word scale is u0.{out_frac} — one shift, matching the
        // convention `exhaustive_error` uses. (The former
        // `1 << (out_frac - 1)` then `/ 2.0` form computed the same
        // value but underflowed the shift for out_frac = 0.)
        self.eval(w) as f64 / (1i64 << cfg.out_frac) as f64
    }

    /// Exhaustive max error vs the true sigmoid.
    pub fn exhaustive_error(&self) -> f64 {
        let cfg = self.tanh.config();
        let half = 1i64 << cfg.mag_bits();
        let inf = cfg.in_format();
        let mut worst = 0.0f64;
        for x in -half..half {
            let got = self.eval(x) as f64 / (1i64 << cfg.out_frac) as f64;
            let want = 1.0 / (1.0 + (-inf.dequantize(x)).exp());
            worst = worst.max((got - want).abs());
        }
        worst
    }
}

/// exp(-2x) unit for x >= 0: the bare velocity-factor product chain.
pub struct ExpUnit {
    cfg: TanhConfig,
    tables: Vec<Vec<i64>>,
}

impl ExpUnit {
    pub fn new(cfg: TanhConfig) -> Result<ExpUnit, String> {
        cfg.validate()?;
        Ok(ExpUnit { cfg, tables: lut_tables(&cfg) })
    }

    /// `e^(-2 * n * 2^-in_frac)` as a u0.{lut_bits} word, for a
    /// non-negative magnitude word `n`.
    pub fn eval(&self, n: i64) -> i64 {
        assert!(n >= 0, "exp unit takes magnitudes (paper: odd-function split)");
        let cfg = &self.cfg;
        let mut f = 0i64;
        for (gi, positions) in cfg.group_positions().iter().enumerate() {
            let mut addr = 0usize;
            for (j, &p) in positions.iter().enumerate() {
                addr |= (((n >> p) & 1) as usize) << j;
            }
            let e = self.tables[gi][addr];
            f = if gi == 0 { e } else { round_mul(f, e, cfg.lut_bits) };
        }
        f
    }

    pub fn out_format(&self) -> QFormat {
        QFormat::new(0, self.cfg.lut_bits)
    }

    /// Float convenience: e^(-2x) for x >= 0.
    pub fn eval_f64(&self, x: f64) -> f64 {
        assert!(x >= 0.0);
        let w = self.cfg.in_format().quantize(x, crate::fixed::Round::Nearest);
        self.eval(w) as f64 / (1i64 << self.cfg.lut_bits) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_accuracy_exhaustive() {
        let s = SigmoidUnit::new(TanhConfig::s3_12()).unwrap();
        // The x/2 pre-shift makes the inner tanh see a grid twice as
        // coarse, so the pre-shift quantization (~2 lsb) dominates:
        // total < 3 output lsb on the stock s3.12 core.
        let e = s.exhaustive_error();
        assert!(e < 3.0 * 2f64.powi(-15), "sigmoid max err {e}");
    }

    #[test]
    fn sigmoid_extra_input_bit_restores_accuracy() {
        // The scalability answer: give the sigmoid flavour one more
        // input fraction bit and the pre-shift cost disappears.
        let cfg = TanhConfig {
            in_int: 3,
            in_frac: 13,
            out_frac: 15,
            lut_bits: 18,
            mult_bits: 16,
            lut_group: 4,
            shuffle: true,
            nr_stages: 3,
            subtractor: crate::tanh::Subtractor::Twos,
        };
        let s = SigmoidUnit::new(cfg).unwrap();
        let e = s.exhaustive_error();
        assert!(e < 2.0 * 2f64.powi(-15), "sigmoid(s3.13) max err {e}");
    }

    #[test]
    fn zero_out_frac_rejected_not_panicking() {
        // Regression: an out_frac = 0 config used to reach eval_f64's
        // `1 << (out_frac - 1)` and panic with a shift underflow in
        // debug builds; construction must fail cleanly instead.
        let mut cfg = TanhConfig::s3_5();
        cfg.out_frac = 0;
        let err = SigmoidUnit::new(cfg).unwrap_err();
        assert!(err.contains("out_frac"), "{err}");
    }

    #[test]
    fn eval_f64_scale_matches_exhaustive_error_convention() {
        // eval_f64 and exhaustive_error must agree on the word scale
        // (u0.{out_frac}): sigma(0) = 0.5 exactly, and a direct word
        // dequantization reproduces the float path.
        let s = SigmoidUnit::new(TanhConfig::s3_12()).unwrap();
        assert_eq!(s.eval_f64(0.0), 0.5);
        let cfg = *s.config();
        for x in [-2.0f64, -0.75, 0.25, 1.5] {
            let w = cfg.in_format().quantize(x, crate::fixed::Round::Nearest);
            let direct = s.eval(w) as f64 / (1i64 << cfg.out_frac) as f64;
            assert_eq!(s.eval_f64(x), direct, "x={x}");
            assert!(
                (s.eval_f64(x) - 1.0 / (1.0 + (-x).exp())).abs() < 1e-3,
                "x={x}"
            );
        }
    }

    #[test]
    fn sigmoid_batch_matches_per_word() {
        let s = SigmoidUnit::new(TanhConfig::s3_12()).unwrap();
        let xs: Vec<i64> = (-32768..32768).step_by(37).collect();
        let want: Vec<i64> = xs.iter().map(|&x| s.eval(x)).collect();
        assert_eq!(s.eval_batch(&xs), want);
    }

    #[test]
    fn sigmoid_fixed_points() {
        let s = SigmoidUnit::new(TanhConfig::s3_12()).unwrap();
        let one = 1i64 << 15;
        assert_eq!(s.eval(0), one / 2); // sigma(0) = 0.5 exactly
        // Large positive -> ~1, large negative -> ~0. Note sigma(7.8)
        // = 0.99959, i.e. ~13 lsb below 1.0 — the unit must NOT
        // saturate early (the tanh domain is halved by the pre-shift).
        assert!(s.eval(32000) > one - 16);
        assert!(s.eval(-32000) < 16);
        assert_eq!(s.eval(32000) + s.eval(-32000), one);
    }

    #[test]
    fn sigmoid_complement_symmetry() {
        // sigma(-x) = 1 - sigma(x): holds to 1 lsb through the unit.
        let s = SigmoidUnit::new(TanhConfig::s3_12()).unwrap();
        let one = 1i64 << 15;
        for x in [2i64, 100, 5001, 20000] {
            let a = s.eval(x);
            let b = s.eval(-x);
            assert!((a + b - one).abs() <= 1, "x={x}: {a} + {b} != {one}");
        }
    }

    #[test]
    fn exp_matches_f64_reference() {
        let e = ExpUnit::new(TanhConfig::s3_12()).unwrap();
        for n in [0i64, 1, 100, 4096, 8192, 20000] {
            let x = n as f64 / 4096.0;
            let got = e.eval(n) as f64 / 262144.0;
            let want = (-2.0 * x).exp();
            assert!(
                (got - want).abs() < 3e-5,
                "n={n}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn exp_of_zero_is_one() {
        let e = ExpUnit::new(TanhConfig::s3_12()).unwrap();
        assert_eq!(e.eval(0), 1 << 18);
    }

    #[test]
    fn exp_monotone_decreasing() {
        let e = ExpUnit::new(TanhConfig::s3_12()).unwrap();
        let mut prev = (1i64 << 18) + 1;
        for n in (0..32768).step_by(97) {
            let v = e.eval(n);
            assert!(v <= prev, "non-monotone at {n}");
            prev = v + 1; // allow 1 ulp of chained-rounding jitter
        }
    }

    #[test]
    #[should_panic(expected = "magnitudes")]
    fn exp_rejects_negative() {
        ExpUnit::new(TanhConfig::s3_12()).unwrap().eval(-1);
    }
}
