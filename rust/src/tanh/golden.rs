//! Golden bit-accurate model: the rust transcription of the datapath
//! spec (`python/compile/kernels/config.py` §5 of DESIGN.md).
//!
//! This is the readable straight-line version used as the oracle for the
//! RTL simulator, the Verilog testbench and the PJRT artifacts. The
//! serving hot path lives in [`super::unit::TanhUnit`], which must agree
//! with this word-for-word (property-tested).

use crate::fixed::rint;

use super::config::{Subtractor, TanhConfig};
use super::lut::lut_tables;
use super::newton::nr_recip;

/// Evaluate one input word through the full datapath.
///
/// `x` is a signed input word in s{in_int}.{in_frac}; the result is a
/// signed output word in s.{out_frac}.
pub fn tanh_golden(x: i64, cfg: &TanhConfig) -> i64 {
    let tables = lut_tables(cfg);
    tanh_golden_with_tables(x, cfg, &tables)
}

/// As [`tanh_golden`] but with prebuilt tables (batch callers).
pub fn tanh_golden_with_tables(x: i64, cfg: &TanhConfig, tables: &[Vec<i64>]) -> i64 {
    let sign = x < 0;
    let n = x.unsigned_abs() as i64;
    let one_l = 1i64 << cfg.lut_bits;

    // 1. Saturation region: |x| >= atanh(1 - 2^-out_frac).
    if n >= cfg.sat_threshold() {
        let t = cfg.out_max();
        return if sign { -t } else { t };
    }

    // 2. Grouped LUT product chain (eq. 7, Table I).
    let mut f = 0i64;
    for (gi, positions) in cfg.group_positions().iter().enumerate() {
        let mut addr = 0usize;
        for (j, &p) in positions.iter().enumerate() {
            addr |= (((n >> p) & 1) as usize) << j;
        }
        let entry = tables[gi][addr];
        f = if gi == 0 {
            entry
        } else {
            crate::fixed::round_mul(f, entry, cfg.lut_bits)
        };
    }

    // 3. Output stage: num = 1 - f, den = 1 + f (bit concat).
    let num = match cfg.subtractor {
        Subtractor::Twos => one_l - f,
        Subtractor::Ones => (one_l - 1) - f,
    };
    let den = one_l + f;

    let mut t = if cfg.nr_stages == 0 {
        // Reference float divider + fixed-point conversion (Table II row 0).
        rint(num as f64 / den as f64 * (1i64 << cfg.out_frac) as f64)
    } else {
        // 4. d = (1+f)/2 truncated to M fractional bits (eq. 11).
        let d = den >> (cfg.lut_bits + 1 - cfg.mult_bits);
        // 5. NR reciprocal.
        let recip = nr_recip(d, cfg);
        // 6. tanh = num * recip / 2, rounded into the output format.
        let shift = cfg.lut_bits + cfg.mult_bits + 1 - cfg.out_frac;
        (num * recip + (1i64 << (shift - 1))) >> shift
    };

    t = t.clamp(0, cfg.out_max());
    if sign {
        -t
    } else {
        t
    }
}

/// Batch evaluation with table reuse.
pub fn tanh_golden_batch(xs: &[i64], cfg: &TanhConfig) -> Vec<i64> {
    let tables = lut_tables(cfg);
    xs.iter()
        .map(|&x| tanh_golden_with_tables(x, cfg, &tables))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{ErrorStats, QFormat};

    fn sweep_error(cfg: &TanhConfig) -> ErrorStats {
        let half = 1i64 << cfg.mag_bits();
        let tables = lut_tables(cfg);
        let inf = cfg.in_format();
        let outf = cfg.out_format();
        ErrorStats::collect((-half..half).map(|x| {
            let got = outf.dequantize(tanh_golden_with_tables(x, cfg, &tables));
            let want = inf.dequantize(x).tanh();
            (x, got, want)
        }))
    }

    #[test]
    fn zero_is_zero() {
        assert_eq!(tanh_golden(0, &TanhConfig::s3_12()), 0);
    }

    #[test]
    fn odd_symmetry() {
        let cfg = TanhConfig::s3_12();
        for x in [1i64, 7, 100, 4096, 20000, 32767] {
            assert_eq!(tanh_golden(x, &cfg), -tanh_golden(-x, &cfg));
        }
    }

    #[test]
    fn saturation() {
        let cfg = TanhConfig::s3_12();
        assert_eq!(tanh_golden(cfg.sat_threshold(), &cfg), cfg.out_max());
        assert_eq!(tanh_golden(-32768, &cfg), -cfg.out_max());
    }

    #[test]
    fn table2_nr3_error_band() {
        // Paper Table II: 4.44e-5 for NR3/2's. Same band here.
        let stats = sweep_error(&TanhConfig::s3_12());
        assert!(stats.max_abs < 7.7e-5, "max err {}", stats.max_abs);
        assert!(stats.max_lsb(QFormat::new(0, 15)) < 2.6);
    }

    #[test]
    fn table2_nr2_error_band() {
        // Paper Table II: 2.56e-4 for NR2/2's.
        let stats = sweep_error(&TanhConfig::s3_12().with_nr(2));
        assert!(stats.max_abs > 1e-4 && stats.max_abs < 6e-4,
                "max err {}", stats.max_abs);
    }

    #[test]
    fn ref_divider_within_one_lsb() {
        let stats = sweep_error(&TanhConfig::s3_12().with_nr(0));
        assert!(stats.max_lsb(QFormat::new(0, 15)) < 1.05);
    }

    #[test]
    fn eight_bit_exhaustive_within_lsb() {
        let cfg = TanhConfig::s3_5();
        let stats = sweep_error(&cfg);
        assert!(stats.max_lsb(QFormat::new(0, 7)) <= 1.01,
                "max err {} lsb", stats.max_lsb(QFormat::new(0, 7)));
    }

    #[test]
    fn monotone_within_noise() {
        let cfg = TanhConfig::s3_12();
        let tables = lut_tables(&cfg);
        let mut prev = -cfg.out_max() - 2;
        for x in (-32768..32768).step_by(17) {
            let y = tanh_golden_with_tables(x, &cfg, &tables);
            assert!(y >= prev - 2, "non-monotone at {x}: {y} < {prev}");
            prev = y;
        }
    }

    #[test]
    fn group_size_one_matches_group_size_four() {
        // Different LUT groupings change rounding by at most ~2 output lsb
        // but the headline accuracy band must be preserved.
        let s1 = sweep_error(&TanhConfig::s3_12().with_group(1));
        let s4 = sweep_error(&TanhConfig::s3_12());
        assert!(s1.max_abs < 1e-4 && s4.max_abs < 1e-4);
    }

    #[test]
    fn shuffle_no_worse_than_sequential() {
        // §IV.B.3: shuffled addressing should not lose accuracy.
        let shuf = sweep_error(&TanhConfig::s3_12());
        let seq = sweep_error(&TanhConfig::s3_12().with_shuffle(false));
        assert!(shuf.max_abs <= seq.max_abs * 1.5);
    }
}
