//! Newton-Raphson reciprocal (paper fig. 4, eq. 8).
//!
//! Computes `1/d` for `d ∈ [0.5, 1]` held as a u1.M word, with every
//! product rounded to M fractional bits (the paper's fixed multiplier
//! precision). Seed: `x0 = 2.75 - 2d` (see `TanhConfig::nr_seed_const`).

use crate::fixed::round_mul;

use super::config::TanhConfig;

/// One NR refinement: `x <- x * (2 - d * x)` at M fractional bits.
#[inline(always)]
pub fn nr_step(d: i64, x: i64, m: u32) -> i64 {
    let t = round_mul(d, x, m);
    round_mul(x, (2i64 << m) - t, m)
}

/// Full reciprocal: seed + `stages` refinements. `d` is u1.M in
/// `[2^(M-1), 2^M]`; the result is u1.M in `[2^M, 2^(M+1)]` (≈ 1/d).
#[inline(always)]
pub fn nr_recip(d: i64, cfg: &TanhConfig) -> i64 {
    let m = cfg.mult_bits;
    let mut x = cfg.nr_seed_const() - (d << 1);
    for _ in 0..cfg.nr_stages {
        x = nr_step(d, x, m);
    }
    x
}

/// Relative error of the fixed-point reciprocal vs exact, for analysis.
pub fn recip_rel_error(d: i64, cfg: &TanhConfig) -> f64 {
    let m = cfg.mult_bits;
    let df = d as f64 / (1i64 << m) as f64;
    let exact = 1.0 / df;
    let got = nr_recip(d, cfg) as f64 / (1i64 << m) as f64;
    (got - exact).abs() / exact
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tanh::config::TanhConfig;

    #[test]
    fn converges_over_full_domain() {
        let cfg = TanhConfig::s3_12(); // nr_stages = 3, M = 16
        let m = cfg.mult_bits;
        let (lo, hi) = (1i64 << (m - 1), 1i64 << m);
        let mut worst = 0.0f64;
        let mut d = lo;
        while d <= hi {
            worst = worst.max(recip_rel_error(d, &cfg));
            d += 7; // stride: full scan is done in the analysis bench
        }
        // 3 stages + 16-bit mults: relative error near quantization floor.
        assert!(worst < 1e-4, "worst rel err {worst}");
    }

    #[test]
    fn two_stages_visibly_worse_than_three() {
        let c3 = TanhConfig::s3_12();
        let c2 = TanhConfig::s3_12().with_nr(2);
        let m = c3.mult_bits;
        let mut w2 = 0.0f64;
        let mut w3 = 0.0f64;
        let mut d = 1i64 << (m - 1);
        while d <= 1i64 << m {
            w2 = w2.max(recip_rel_error(d, &c2));
            w3 = w3.max(recip_rel_error(d, &c3));
            d += 13;
        }
        assert!(w2 > 2.0 * w3, "NR2 {w2} vs NR3 {w3}");
    }

    #[test]
    fn exact_at_endpoints() {
        // d = 1.0 -> 1/d = 1.0; d = 0.5 -> 1/d = 2.0.
        let cfg = TanhConfig::s3_12();
        let m = cfg.mult_bits;
        let one = 1i64 << m;
        assert!((nr_recip(one, &cfg) - one).abs() <= 2);
        assert!((nr_recip(one / 2, &cfg) - 2 * one).abs() <= 4);
    }

    #[test]
    fn zero_stages_returns_seed() {
        let cfg = TanhConfig::s3_12().with_nr(0);
        let m = cfg.mult_bits;
        let d = 3i64 << (m - 2); // 0.75
        assert_eq!(nr_recip(d, &cfg), cfg.nr_seed_const() - (d << 1));
    }
}
