//! Runtime-selected SIMD batch kernels for the serving hot path.
//!
//! Three process-wide modes, selected once via `TANHVF_SIMD`:
//!
//! * `off`    — per-word [`super::unit::TanhUnit::eval`] calls (the
//!   pre-vectorization behavior, kept as a CI leg).
//! * `scalar` — the portable hoisted batch loops (no intrinsics).
//! * `avx2`   — 4x64-bit-lane AVX2 kernels (`std::arch`), used only
//!   when the CPU reports the feature at runtime; requesting `avx2` on
//!   a host without it degrades to `scalar`. Unset picks `avx2` when
//!   available, else `scalar`.
//!
//! Every AVX2 kernel is **bit-exact** against the scalar datapath — the
//! property tests in `tests/simd_bitexact.rs` enforce this against
//! [`super::golden`] for every precision preset. Bit-exactness is load
//! bearing: the multi-node CI byte-compares `/v1/batch` responses
//! across nodes, so a node that vectorizes and a node that doesn't must
//! agree on every word.
//!
//! ## Lane layout and shift discipline
//!
//! The datapath kernel processes 4 input words per iteration as packed
//! 64-bit lanes. AVX2 has no 64-bit *arithmetic* right shift
//! (`_mm256_srai_epi64` is AVX-512), so every shifted intermediate is
//! proven non-negative and shifted logically:
//!
//! * LUT chain: `f, e` are u0.L words in `(0, 2^L]`, so the rounded
//!   product `(f*e + 2^(L-1))` is positive.
//! * NR: the seed `2.75*2^M - 2d` with `d` in `(2^(M-1), 2^M]` is in
//!   `(0.75*2^M, 1.75*2^M)`; iterates stay in `(0, ~2^(M+1))` and
//!   `2^(M+1) - t0 > 0` (NR for `2^(2M)/d` converges from below).
//! * Recompose: with `L >= out_frac + 3` the rounding constant
//!   `2^(shift-1) >= 2^(M+3)` dominates `|num * xr| <= xr < 2^(M+2)`
//!   even for the one's-complement `num = -1` case, keeping the
//!   pre-shift sum non-negative.
//!
//! `_mm256_mul_epi32` multiplies the sign-extended low 32 bits of each
//! lane; the eligibility gate (`L, M <= 26`) bounds every factor below
//! `2^28`, so the low-DWORD product equals the full i64 product.
//!
//! Saturated lanes are computed anyway (their gather addresses are
//! formed bit-by-bit, so they stay in bounds for *any* input word) and
//! the `±out_max` result is blended in at the end — branch-free, and
//! identical to the scalar early return.

use super::config::TanhConfig;
use std::sync::OnceLock;

/// Which batch kernel the process uses (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Per-word scalar calls — no batch fast path at all.
    Off,
    /// Portable hoisted batch loops.
    Scalar,
    /// AVX2 intrinsics (x86-64 with runtime feature detection).
    Avx2,
}

impl SimdMode {
    pub fn name(&self) -> &'static str {
        match self {
            SimdMode::Off => "off",
            SimdMode::Scalar => "scalar",
            SimdMode::Avx2 => "avx2",
        }
    }
}

/// Does this CPU support the AVX2 kernels?
pub fn avx2_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The process-wide mode: `TANHVF_SIMD` if set (unsupported `avx2`
/// degrades to `scalar`), else auto-detect. Read once and cached.
pub fn active() -> SimdMode {
    static MODE: OnceLock<SimdMode> = OnceLock::new();
    *MODE.get_or_init(|| match std::env::var("TANHVF_SIMD").as_deref() {
        Ok("off") => SimdMode::Off,
        Ok("scalar") => SimdMode::Scalar,
        _ => {
            // "avx2" and auto both take the best the host offers.
            if avx2_supported() {
                SimdMode::Avx2
            } else {
                SimdMode::Scalar
            }
        }
    })
}

/// Can the live datapath for `cfg` run in the AVX2 kernel bit-exactly?
///
/// Delegates to [`crate::analysis::verify::simd_gate`]: the bounds
/// (`SIMD_MIN_NR_STAGES`, `SIMD_MIN_LUT_MARGIN`, `SIMD_MAX_LUT_BITS`,
/// `SIMD_MAX_MULT_BITS`) live next to the static verifier that proves
/// them sound — every admitted config has verifier-proved exact low-32
/// multiplies and non-negative shift operands (the grid sweep in
/// `tests/verify_datapath.rs` enforces "admitted implies provable").
///
/// Both canonical presets and every `named_config`-derived point
/// (`L = out_frac + 3` by construction) qualify. Ineligible configs
/// silently use the scalar batch loop.
pub(crate) fn datapath_eligible(cfg: &TanhConfig) -> bool {
    crate::analysis::verify::simd_gate(cfg)
}

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    use crate::tanh::config::Subtractor;
    use crate::tanh::unit::{Group, TanhUnit};
    use std::arch::x86_64::*;

    /// Product of the sign-extended low 32 bits of each 64-bit lane.
    /// Exact for the full i64 product whenever both lane values fit in
    /// i32 — the eligibility gate guarantees that for every call site.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn mul_lo32(a: __m256i, b: __m256i) -> __m256i {
        _mm256_mul_epi32(a, b)
    }

    /// Gather one LUT group's entries for 4 magnitude lanes: form each
    /// lane's address bit-by-bit from the group's input-bit positions,
    /// add the group's offset into the flat table, gather 64-bit
    /// entries. Addresses are `< 2^positions.len()` by construction, so
    /// the gather is in bounds for any lane value (even saturated
    /// garbage that gets blended away later).
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn gather_group(
        tables: *const i64,
        g: &Group,
        n: __m256i,
    ) -> __m256i {
        let one = _mm256_set1_epi64x(1);
        let mut addr = _mm256_setzero_si256();
        for (j, &p) in g.positions.iter().enumerate() {
            let bit = _mm256_and_si256(
                _mm256_srl_epi64(n, _mm_cvtsi32_si128(p as i32)),
                one,
            );
            addr = _mm256_or_si256(
                addr,
                _mm256_sll_epi64(bit, _mm_cvtsi32_si128(j as i32)),
            );
        }
        // Offsets are not address-aligned: add, don't or.
        let idx = _mm256_add_epi64(addr, _mm256_set1_epi64x(g.offset as i64));
        _mm256_i64gather_epi64::<8>(tables, idx)
    }

    /// Memoized path: 4-lane table gather, i64 words.
    ///
    /// # Safety
    /// AVX2 must be available and every `xs[i] - lo` must index into
    /// `table` (the caller pre-scans).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn gather_memo_i64(
        table: &[i32],
        lo: i64,
        xs: &[i64],
        out: &mut [i64],
    ) {
        debug_assert_eq!(xs.len(), out.len());
        let base = table.as_ptr();
        let lo_v = _mm256_set1_epi64x(lo);
        let vend = xs.len() / 4 * 4;
        let mut i = 0;
        while i < vend {
            let x = _mm256_loadu_si256(xs.as_ptr().add(i).cast());
            let idx = _mm256_sub_epi64(x, lo_v);
            // Table entries can be negative: sign-extend the gathered
            // 32-bit words.
            let vals = _mm256_i64gather_epi32::<4>(base, idx);
            let wide = _mm256_cvtepi32_epi64(vals);
            _mm256_storeu_si256(out.as_mut_ptr().add(i).cast(), wide);
            i += 4;
        }
        for j in vend..xs.len() {
            out[j] = table[(xs[j] - lo) as usize] as i64;
        }
    }

    /// Memoized path: 8-lane table gather, i32 words (the PJRT I/O
    /// type — twice the lane density of the i64 path).
    ///
    /// # Safety
    /// AVX2 must be available and every `xs[i] + bias` must index into
    /// `table` (the caller pre-scans).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn gather_memo_i32(
        table: &[i32],
        bias: i32,
        xs: &[i32],
        out: &mut [i32],
    ) {
        debug_assert_eq!(xs.len(), out.len());
        let base = table.as_ptr();
        let bias_v = _mm256_set1_epi32(bias);
        let vend = xs.len() / 8 * 8;
        let mut i = 0;
        while i < vend {
            let x = _mm256_loadu_si256(xs.as_ptr().add(i).cast());
            let idx = _mm256_add_epi32(x, bias_v);
            let vals = _mm256_i32gather_epi32::<4>(base, idx);
            _mm256_storeu_si256(out.as_mut_ptr().add(i).cast(), vals);
            i += 8;
        }
        for j in vend..xs.len() {
            out[j] = table[(xs[j] + bias) as usize];
        }
    }

    /// The live velocity-factor datapath, 4 words per iteration.
    /// Bit-exact vs [`TanhUnit::eval_datapath`] for any input words
    /// (see the module-level shift/overflow proofs).
    ///
    /// # Safety
    /// AVX2 must be available and `datapath_eligible(unit.config())`
    /// must hold.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn datapath_avx2(
        unit: &TanhUnit,
        xs: &[i64],
        out: &mut [i64],
    ) {
        debug_assert_eq!(xs.len(), out.len());
        let cfg = unit.config();
        let l = cfg.lut_bits;
        let m = cfg.mult_bits;
        let half_l = _mm256_set1_epi64x(1i64 << (l - 1));
        let one_l = _mm256_set1_epi64x(1i64 << l);
        let half_m = _mm256_set1_epi64x(1i64 << (m - 1));
        let two_m = _mm256_set1_epi64x(2i64 << m);
        let seed = _mm256_set1_epi64x(cfg.nr_seed_const());
        let sat_m1 = _mm256_set1_epi64x(unit.sat_threshold - 1);
        let out_max = _mm256_set1_epi64x(unit.out_max);
        let zero = _mm256_setzero_si256();
        let l_shift = _mm_cvtsi32_si128(l as i32);
        let m_shift = _mm_cvtsi32_si128(m as i32);
        let d_shift = _mm_cvtsi32_si128((l + 1 - m) as i32);
        let o_amt = l + m + 1 - cfg.out_frac;
        let o_shift = _mm_cvtsi32_si128(o_amt as i32);
        let o_round = _mm256_set1_epi64x(1i64 << (o_amt - 1));
        let num_base = _mm256_set1_epi64x(match cfg.subtractor {
            Subtractor::Twos => 1i64 << l,
            Subtractor::Ones => (1i64 << l) - 1,
        });
        let tables = unit.tables.as_ptr();

        let vend = xs.len() / 4 * 4;
        let mut i = 0;
        while i < vend {
            let x = _mm256_loadu_si256(xs.as_ptr().add(i).cast());
            // |x| via two's complement: (x ^ m) - m, m = sign mask.
            let negm = _mm256_cmpgt_epi64(zero, x);
            let n = _mm256_sub_epi64(_mm256_xor_si256(x, negm), negm);
            let satm = _mm256_cmpgt_epi64(n, sat_m1);

            // LUT product chain: f = prod of group entries, u0.L.
            let mut f = gather_group(tables, &unit.groups[0], n);
            for g in &unit.groups[1..] {
                let e = gather_group(tables, g, n);
                let p = _mm256_add_epi64(mul_lo32(f, e), half_l);
                f = _mm256_srl_epi64(p, l_shift);
            }

            // Output stage: num/den, NR reciprocal, recompose.
            let num = _mm256_sub_epi64(num_base, f);
            let den = _mm256_add_epi64(one_l, f);
            let d = _mm256_srl_epi64(den, d_shift);
            let mut xr = _mm256_sub_epi64(seed, _mm256_slli_epi64::<1>(d));
            for _ in 0..cfg.nr_stages {
                let t0 = _mm256_srl_epi64(
                    _mm256_add_epi64(mul_lo32(d, xr), half_m),
                    m_shift,
                );
                xr = _mm256_srl_epi64(
                    _mm256_add_epi64(
                        mul_lo32(xr, _mm256_sub_epi64(two_m, t0)),
                        half_m,
                    ),
                    m_shift,
                );
            }
            let t = _mm256_srl_epi64(
                _mm256_add_epi64(mul_lo32(num, xr), o_round),
                o_shift,
            );

            // clamp(0, out_max), saturation blend, conditional negate.
            let t = _mm256_blendv_epi8(t, zero, _mm256_cmpgt_epi64(zero, t));
            let t =
                _mm256_blendv_epi8(t, out_max, _mm256_cmpgt_epi64(t, out_max));
            let t = _mm256_blendv_epi8(t, out_max, satm);
            let t = _mm256_sub_epi64(_mm256_xor_si256(t, negm), negm);
            _mm256_storeu_si256(out.as_mut_ptr().add(i).cast(), t);
            i += 4;
        }
        for j in vend..xs.len() {
            out[j] = unit.eval_datapath(xs[j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_datapath_eligible() {
        assert!(datapath_eligible(&TanhConfig::s3_12()));
        assert!(datapath_eligible(&TanhConfig::s3_5()));
    }

    #[test]
    fn float_divider_and_fat_luts_fall_back() {
        assert!(!datapath_eligible(&TanhConfig::s3_12().with_nr(0)));
        let mut fat = TanhConfig::s3_5();
        fat.lut_bits = 27;
        assert!(!datapath_eligible(&fat));
        let mut narrow = TanhConfig::s3_5();
        narrow.lut_bits = narrow.out_frac + 2;
        assert!(!datapath_eligible(&narrow));
    }

    #[test]
    fn active_mode_is_cached_and_valid() {
        let a = active();
        assert_eq!(a, active());
        if a == SimdMode::Avx2 {
            assert!(avx2_supported());
        }
        assert!(!a.name().is_empty());
    }
}
