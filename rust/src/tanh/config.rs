//! Datapath configuration — the rust mirror of the cross-layer spec in
//! `python/compile/kernels/config.py`. Field semantics, derived
//! quantities and defaults must match bit-for-bit; the golden-vector
//! integration tests (`rust/tests/golden_vectors.rs`) enforce this.

use crate::fixed::QFormat;

/// Final-stage subtractor implementation for `1 - f` (paper §IV.B.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Subtractor {
    /// True two's complement: `2^L - f`.
    Twos,
    /// One's complement approximation: `~f = 2^L - 1 - f` (cheaper:
    /// drops the carry chain; costs <= 1 lsb of f).
    Ones,
}

impl Subtractor {
    pub fn name(&self) -> &'static str {
        match self {
            Subtractor::Twos => "2's",
            Subtractor::Ones => "1's",
        }
    }
}

/// Static parameters of one hardware instance of the tanh unit.
///
/// Canonical instances: [`TanhConfig::s3_12`] (16-bit, Tables II/III) and
/// [`TanhConfig::s3_5`] (8-bit, Table IV).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TanhConfig {
    /// Integer bits of the input format.
    pub in_int: u32,
    /// Fractional bits of the input format.
    pub in_frac: u32,
    /// Fractional bits of the (sign + fraction) output format.
    pub out_frac: u32,
    /// Velocity-factor LUT precision L (entries are u0.L).
    pub lut_bits: u32,
    /// Multiplier fractional precision M in the NR/recompose path.
    pub mult_bits: u32,
    /// Bits per LUT group (1 = per-bit registers, 4 = paper's choice).
    pub lut_group: u32,
    /// Bit-shuffled LUT addressing (paper §IV.B.3).
    pub shuffle: bool,
    /// NR iterations; 0 = reference float divider (Table II row 0).
    pub nr_stages: u32,
    /// Final-stage subtractor flavour.
    pub subtractor: Subtractor,
}

impl Default for TanhConfig {
    fn default() -> Self {
        Self::s3_12()
    }
}

impl TanhConfig {
    /// 16-bit operating point: s3.12 in, s.15 out, L=18, M=16, 4-bit LUTs.
    pub const fn s3_12() -> Self {
        TanhConfig {
            in_int: 3,
            in_frac: 12,
            out_frac: 15,
            lut_bits: 18,
            mult_bits: 16,
            lut_group: 4,
            shuffle: true,
            nr_stages: 3,
            subtractor: Subtractor::Twos,
        }
    }

    /// 8-bit operating point: s3.5 in, s.7 out, L=10, M=9, 3-bit LUTs.
    pub const fn s3_5() -> Self {
        TanhConfig {
            in_int: 3,
            in_frac: 5,
            out_frac: 7,
            lut_bits: 10,
            mult_bits: 9,
            lut_group: 3,
            shuffle: true,
            nr_stages: 3,
            subtractor: Subtractor::Twos,
        }
    }

    pub fn with_nr(mut self, stages: u32) -> Self {
        self.nr_stages = stages;
        self
    }

    pub fn with_subtractor(mut self, sub: Subtractor) -> Self {
        self.subtractor = sub;
        self
    }

    pub fn with_group(mut self, g: u32) -> Self {
        self.lut_group = g;
        self
    }

    pub fn with_shuffle(mut self, s: bool) -> Self {
        self.shuffle = s;
        self
    }

    /// Validate invariants (mirrors the python `__post_init__`).
    pub fn validate(&self) -> Result<(), String> {
        if self.in_frac < 1 || self.out_frac < 1 {
            return Err(format!("invalid format: {self:?}"));
        }
        if self.lut_bits + 1 < self.mult_bits {
            return Err("lut_bits must be >= mult_bits - 1".into());
        }
        if self.lut_group < 1 {
            return Err("lut_group must be >= 1".into());
        }
        if self.nr_stages > 4 {
            return Err("nr_stages must be in {0..4}".into());
        }
        if self.in_int + self.in_frac + self.lut_bits + self.mult_bits > 58 {
            return Err("combined precision exceeds i64 headroom".into());
        }
        Ok(())
    }

    // ---- derived geometry --------------------------------------------

    /// Magnitude bits of the input (sign stripped).
    pub const fn mag_bits(&self) -> u32 {
        self.in_int + self.in_frac
    }

    pub const fn in_width(&self) -> u32 {
        1 + self.mag_bits()
    }

    pub const fn out_width(&self) -> u32 {
        1 + self.out_frac
    }

    /// Largest representable output word: `1 - 2^-out_frac`.
    pub const fn out_max(&self) -> i64 {
        (1i64 << self.out_frac) - 1
    }

    pub const fn num_groups(&self) -> u32 {
        (self.mag_bits() + self.lut_group - 1) / self.lut_group
    }

    pub fn in_format(&self) -> QFormat {
        QFormat::new(self.in_int, self.in_frac)
    }

    pub fn out_format(&self) -> QFormat {
        QFormat::new(0, self.out_frac)
    }

    /// Smallest input magnitude word that saturates the output
    /// (`ceil(atanh(1 - 2^-out_frac) * 2^in_frac)`, paper §IV).
    pub fn sat_threshold(&self) -> i64 {
        let dom = (1.0 - (-(self.out_frac as f64)).exp2()).atanh();
        (dom * (1i64 << self.in_frac) as f64).ceil() as i64
    }

    /// NR linear-seed constant: `2.75 * 2^M` (see python spec for why
    /// 2.75 = 0b10.11 rather than Kornerup-Muller's 2.9142).
    pub const fn nr_seed_const(&self) -> i64 {
        11i64 << (self.mult_bits - 2)
    }

    /// Bit positions (lsb = 0) addressed by each LUT group.
    ///
    /// `shuffle` deals positions round-robin so every group mixes small
    /// and large place values (paper §IV.B.3); otherwise consecutive.
    pub fn group_positions(&self) -> Vec<Vec<u32>> {
        let n = self.mag_bits();
        let g = self.num_groups();
        if self.shuffle {
            (0..g).map(|j| (j..n).step_by(g as usize).collect()).collect()
        } else {
            (0..g)
                .map(|j| {
                    (j * self.lut_group..((j + 1) * self.lut_group).min(n))
                        .collect()
                })
                .collect()
        }
    }

    /// Human-readable description matching the python `describe()`.
    pub fn describe(&self) -> String {
        format!(
            "s{}.{}->s.{} L={} M={} g={} {} nr={} {}",
            self.in_int,
            self.in_frac,
            self.out_frac,
            self.lut_bits,
            self.mult_bits,
            self.lut_group,
            if self.shuffle { "shuf" } else { "seq" },
            self.nr_stages,
            self.subtractor.name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_geometry() {
        let c = TanhConfig::s3_12();
        assert_eq!(c.mag_bits(), 15);
        assert_eq!(c.in_width(), 16);
        assert_eq!(c.out_width(), 16);
        assert_eq!(c.out_max(), 32767);
        assert_eq!(c.num_groups(), 4);
        c.validate().unwrap();

        let c8 = TanhConfig::s3_5();
        assert_eq!(c8.mag_bits(), 8);
        assert_eq!(c8.num_groups(), 3);
        c8.validate().unwrap();
    }

    #[test]
    fn sat_threshold_matches_paper_domain() {
        // Paper §IV: ±5.55 for 16-bit out, ±2.77 for 8-bit out.
        let t16 = TanhConfig::s3_12().sat_threshold() as f64 / 4096.0;
        assert!((t16 - 5.55).abs() < 0.01, "{t16}");
        let t8 = TanhConfig::s3_5().sat_threshold() as f64 / 32.0;
        assert!((t8 - 2.78).abs() < 0.04, "{t8}");
    }

    #[test]
    fn seed_constant() {
        assert_eq!(TanhConfig::s3_12().nr_seed_const(), (2.75 * 65536.0) as i64);
        assert_eq!(TanhConfig::s3_5().nr_seed_const(), (2.75 * 512.0) as i64);
    }

    #[test]
    fn group_positions_partition() {
        for cfg in [TanhConfig::s3_12(), TanhConfig::s3_5(),
                    TanhConfig::s3_12().with_shuffle(false),
                    TanhConfig::s3_12().with_group(2),
                    TanhConfig::s3_12().with_group(5)] {
            let mut flat: Vec<u32> =
                cfg.group_positions().into_iter().flatten().collect();
            flat.sort_unstable();
            assert_eq!(flat, (0..cfg.mag_bits()).collect::<Vec<_>>(),
                       "{}", cfg.describe());
        }
    }

    #[test]
    fn shuffle_mixes_magnitudes() {
        let cfg = TanhConfig::s3_12();
        for g in cfg.group_positions() {
            let lo = *g.iter().min().unwrap();
            let hi = *g.iter().max().unwrap();
            assert!(lo < cfg.mag_bits() / 2 && hi >= cfg.mag_bits() / 2);
        }
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = TanhConfig::s3_12();
        c.lut_bits = 10;
        assert!(c.validate().is_err());
        let mut c = TanhConfig::s3_12();
        c.nr_stages = 9;
        assert!(c.validate().is_err());
        let mut c = TanhConfig::s3_12();
        c.lut_group = 0;
        assert!(c.validate().is_err());
    }
}
