//! Hand-rolled command-line parser (clap is unavailable offline).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [positional...]`
//! with typed accessors, defaults, required-argument errors and an
//! auto-generated usage string.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed arguments for one invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of tokens (usually `std::env::args().skip(1)`).
    ///
    /// The first non-flag token becomes the subcommand; `--key value` and
    /// `--key=value` both bind; bare `--flag` binds to `"true"`.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` terminator: rest is positional.
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // Peek: next token is a value unless it's another flag.
                    let is_value = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if is_value {
                        out.flags.insert(stripped.to_string(), it.next().unwrap());
                    } else {
                        out.flags.insert(stripped.to_string(), "true".to_string());
                    }
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str_opt(key).unwrap_or(default)
    }

    pub fn required(&self, key: &str) -> Result<&str, CliError> {
        self.str_opt(key)
            .ok_or_else(|| CliError(format!("missing required --{key}")))
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected integer, got '{s}'"))),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        self.u64_or(key, default as u64).map(|v| v as usize)
    }

    pub fn i64_or(&self, key: &str, default: i64) -> Result<i64, CliError> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected integer, got '{s}'"))),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected float, got '{s}'"))),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.str_opt(key), Some("true") | Some("1") | Some("yes"))
    }
}

/// Declarative usage text for a set of subcommands.
pub fn usage(bin: &str, subcommands: &[(&str, &str)]) -> String {
    let mut s = format!("usage: {bin} <subcommand> [options]\n\nsubcommands:\n");
    for (name, desc) in subcommands {
        s.push_str(&format!("  {name:<18} {desc}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["serve", "--port", "8080", "--verbose", "--rate=2.5"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.u64_or("port", 0).unwrap(), 8080);
        assert!(a.bool("verbose"));
        assert_eq!(a.f64_or("rate", 0.0).unwrap(), 2.5);
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&["run", "file1", "file2"]);
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["run", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn missing_required_is_error() {
        let a = parse(&["serve"]);
        assert!(a.required("port").is_err());
    }

    #[test]
    fn bad_int_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.u64_or("n", 3).is_err());
    }

    #[test]
    fn bare_flag_before_value_flag() {
        let a = parse(&["x", "--fast", "--n", "4"]);
        assert!(a.bool("fast"));
        assert_eq!(a.u64_or("n", 0).unwrap(), 4);
    }
}
