//! Regenerates the paper's Table II (error analysis for arithmetic
//! approximations) and times the exhaustive sweep that produces it.

use tanh_vf::analysis::exhaustive_error;
use tanh_vf::bench::Bench;
use tanh_vf::tanh::{Subtractor, TanhConfig, TanhUnit};
use tanh_vf::util::table::{sci, Table};

fn main() {
    println!("=== Table II: error analysis (s3.12 -> s.15, exhaustive 2^16) ===\n");
    let mut t = Table::new(&[
        "NR Stages", "Subtractor", "Max Error (measured)", "lsb",
        "Max Error (paper)",
    ]);
    let rows: &[(u32, Subtractor, &str)] = &[
        (0, Subtractor::Twos, "4.44e-5 (float div ref)"),
        (2, Subtractor::Ones, "2.77e-4"),
        (2, Subtractor::Twos, "2.56e-4"),
        (3, Subtractor::Ones, "4.32e-5"),
        (3, Subtractor::Twos, "4.44e-5"),
    ];
    for &(nr, sub, paper) in rows {
        let cfg = TanhConfig::s3_12().with_nr(nr).with_subtractor(sub);
        let unit = TanhUnit::new(cfg).unwrap();
        let stats = exhaustive_error(&unit);
        t.row(&[
            if nr == 0 { "0 (ref)".into() } else { format!("{nr}") },
            sub.name().to_string(),
            sci(stats.max_abs),
            format!("{:.2}", stats.max_lsb(cfg.out_format())),
            paper.to_string(),
        ]);
    }
    println!("{}", t.render());

    // §V sentence: 1's complement drop for the (1-f) subtractor.
    let e_ones = exhaustive_error(
        &TanhUnit::new(TanhConfig::s3_12().with_subtractor(Subtractor::Ones))
            .unwrap(),
    );
    let e_twos = exhaustive_error(&TanhUnit::new(TanhConfig::s3_12()).unwrap());
    println!(
        "1's vs 2's complement subtractor (NR3): {} vs {}  (paper: 5.87e-5 vs 4.32e-5 band)\n",
        sci(e_ones.max_abs),
        sci(e_twos.max_abs)
    );

    println!("--- timing of the exhaustive error sweep ---");
    let unit = TanhUnit::new(TanhConfig::s3_12()).unwrap();
    let mut b = Bench::default();
    b.run_elems("exhaustive_error_sweep_2^16", 65536, || {
        exhaustive_error(&unit).max_abs
    });
}
