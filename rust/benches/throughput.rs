//! Serving-path throughput: golden model vs optimized unit vs memoized
//! unit vs RTL simulation vs PJRT executable vs the full coordinator.
//! This is the §Perf benchmark of EXPERIMENTS.md.

use std::time::Duration;

use tanh_vf::bench::{black_box, Bench};
use tanh_vf::coordinator::{native_factory, Config, Coordinator};
use tanh_vf::rtl::RtlSim;
use tanh_vf::runtime::{artifacts_dir, Runtime, Tensor};
use tanh_vf::synth::datapath::build_tanh_datapath;
use tanh_vf::synth::pipeline::assign_stages;
use tanh_vf::tanh::golden::tanh_golden_batch;
use tanh_vf::tanh::{TanhConfig, TanhUnit};
use tanh_vf::util::rng::Rng;

fn main() {
    let cfg = TanhConfig::s3_12();
    let mut rng = Rng::new(99);
    let n = 1024usize;
    let words: Vec<i64> =
        (0..n).map(|_| rng.range_i64(-32768, 32768)).collect();
    let words32: Vec<i32> = words.iter().map(|&w| w as i32).collect();

    let mut b = Bench::default();

    // 1. Golden model (rebuilds tables per batch — the readable spec).
    b.run_elems("golden_model_batch_1k", n as u64, || {
        black_box(tanh_golden_batch(&words, &cfg))
    });

    // 2. Optimized unit, live datapath.
    let unit = TanhUnit::new(cfg).unwrap();
    let mut out = vec![0i64; n];
    b.run_elems("tanh_unit_live_batch_1k", n as u64, || {
        unit.eval_batch_into(&words, &mut out);
        black_box(out[0])
    });

    // 3. Fully memoized unit (ROM-compiled shape).
    let mut memo = TanhUnit::new(cfg).unwrap();
    memo.precompute_all();
    b.run_elems("tanh_unit_memo_batch_1k", n as u64, || {
        memo.eval_batch_into(&words, &mut out);
        black_box(out[0])
    });

    // 4. Cycle-accurate RTL simulation (7-stage pipeline).
    let net = build_tanh_datapath(&cfg);
    let pipe = assign_stages(&net, 7);
    b.run_elems("rtl_sim_7stage_batch_1k", n as u64, || {
        let mut sim = RtlSim::new(&net, &pipe);
        black_box(sim.run_batch(&words).0.len())
    });

    // 5. PJRT executable (the Pallas kernel, AOT-compiled).
    if artifacts_dir().join("manifest.json").exists() {
        let rt = Runtime::new(&artifacts_dir()).unwrap();
        rt.ensure_compiled("tanh_s3_12").unwrap();
        let input = Tensor::I32(words32.clone());
        b.run_elems("pjrt_pallas_batch_1k", n as u64, || {
            black_box(rt.execute("tanh_s3_12", &[input.clone()]).unwrap())
        });
    } else {
        println!("(skipping PJRT rows: run `make artifacts`)");
    }

    // 6. Full coordinator path (batching + dispatch + scatter).
    let c = Coordinator::start(
        Config {
            batch_capacity: 1024,
            max_wait: Duration::from_micros(200),
            workers: 2,
            queue_limit: 8192,
        },
        native_factory(cfg, true),
    );
    b.run_elems("coordinator_roundtrip_256w", 256, || {
        black_box(c.eval_blocking(words32[..256].to_vec()).unwrap())
    });

    // Perf summary vs targets (DESIGN.md §9).
    println!("\n--- perf targets ---");
    if let Some(m) = b.get("tanh_unit_memo_batch_1k") {
        let tp = m.throughput().unwrap();
        println!(
            "memoized unit: {:.2e} tanh/s (target >= 1e8): {}",
            tp,
            if tp >= 1e8 { "MET" } else { "MISSED" }
        );
    }
    if let (Some(unit_m), Some(coord)) = (
        b.get("tanh_unit_memo_batch_1k"),
        b.get("coordinator_roundtrip_256w"),
    ) {
        let per_word_unit = unit_m.mean_ns / 1024.0;
        let per_word_coord = coord.mean_ns / 256.0;
        println!(
            "coordinator overhead: {:.1} ns/word vs {:.2} ns/word raw \
             (batching window dominates at low load — see EXPERIMENTS.md)",
            per_word_coord, per_word_unit
        );
    }
}
