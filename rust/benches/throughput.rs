//! Serving-path throughput: golden model vs optimized unit vs memoized
//! unit vs RTL simulation vs PJRT executable vs the full coordinator.
//! This is the §Perf benchmark of EXPERIMENTS.md.
//!
//! The SIMD section pins the batch kernels to each [`SimdMode`] so the
//! vector path is measured against the exact scalar loop it replaces,
//! asserts the AVX2 live-datapath speedup floor (>= 1.5x) on hosts
//! that have the feature, and persists every row's elements/sec to
//! `BENCH_throughput.json` for the CI smoke leg. `TANHVF_BENCH_QUICK=1`
//! trades statistical depth for wall-clock time.

use std::time::Duration;

use tanh_vf::analysis::TanhImpl;
use tanh_vf::baselines::dctif::Dctif;
use tanh_vf::baselines::fmt16;
use tanh_vf::baselines::pwl::Pwl;
use tanh_vf::baselines::ralut::RangeLut;
use tanh_vf::bench::{black_box, Bench};
use tanh_vf::coordinator::{native_factory, Config, Coordinator};
use tanh_vf::rtl::RtlSim;
use tanh_vf::runtime::{artifacts_dir, Runtime, Tensor};
use tanh_vf::synth::datapath::build_tanh_datapath;
use tanh_vf::synth::pipeline::assign_stages;
use tanh_vf::tanh::golden::tanh_golden_batch;
use tanh_vf::tanh::{simd, SigmoidUnit, SimdMode, TanhConfig, TanhUnit};
use tanh_vf::util::json::{self, Json};
use tanh_vf::util::rng::Rng;

fn main() {
    let cfg = TanhConfig::s3_12();
    let mut rng = Rng::new(99);
    let n = 1024usize;
    let words: Vec<i64> =
        (0..n).map(|_| rng.range_i64(-32768, 32768)).collect();
    let words32: Vec<i32> = words.iter().map(|&w| w as i32).collect();

    let quick = std::env::var("TANHVF_BENCH_QUICK").is_ok();
    let mut b = if quick { Bench::quick() } else { Bench::default() };

    // 1. Golden model (rebuilds tables per batch — the readable spec).
    b.run_elems("golden_model_batch_1k", n as u64, || {
        black_box(tanh_golden_batch(&words, &cfg))
    });

    // 2. Optimized unit, live datapath (auto SIMD mode).
    let unit = TanhUnit::new(cfg).unwrap();
    let mut out = vec![0i64; n];
    b.run_elems("tanh_unit_live_batch_1k", n as u64, || {
        unit.eval_batch_into(&words, &mut out);
        black_box(out[0])
    });

    // 3. Fully memoized unit (ROM-compiled shape, auto SIMD mode).
    let mut memo = TanhUnit::new(cfg).unwrap();
    memo.precompute_all();
    b.run_elems("tanh_unit_memo_batch_1k", n as u64, || {
        memo.eval_batch_into(&words, &mut out);
        black_box(out[0])
    });

    // 4. Cycle-accurate RTL simulation (7-stage pipeline).
    let net = build_tanh_datapath(&cfg);
    let pipe = assign_stages(&net, 7);
    b.run_elems("rtl_sim_7stage_batch_1k", n as u64, || {
        let mut sim = RtlSim::new(&net, &pipe);
        black_box(sim.run_batch(&words).0.len())
    });

    // 5. PJRT executable (the Pallas kernel, AOT-compiled).
    if artifacts_dir().join("manifest.json").exists() {
        let rt = Runtime::new(&artifacts_dir()).unwrap();
        rt.ensure_compiled("tanh_s3_12").unwrap();
        let input = Tensor::I32(words32.clone());
        b.run_elems("pjrt_pallas_batch_1k", n as u64, || {
            black_box(rt.execute("tanh_s3_12", &[input.clone()]).unwrap())
        });
    } else {
        println!("(skipping PJRT rows: run `make artifacts`)");
    }

    // 6. Full coordinator path (batching + dispatch + scatter).
    let c = Coordinator::start(
        Config {
            batch_capacity: 1024,
            max_wait: Duration::from_micros(200),
            workers: 2,
            queue_limit: 8192,
        },
        native_factory(cfg, true),
    );
    b.run_elems("coordinator_roundtrip_256w", 256, || {
        black_box(c.eval_blocking(words32[..256].to_vec()).unwrap())
    });

    // 7. SIMD kernel matrix: the same batch pinned to each mode, so
    //    the vector path is measured against the exact scalar loop it
    //    replaces. `eval_batch_mode(Avx2)` silently falls back on
    //    hosts without the feature, so those rows are gated on
    //    detection rather than emitting dishonest numbers.
    let avx2 = simd::avx2_supported();
    b.run_elems("tanh_unit_live_off_batch_1k", n as u64, || {
        unit.eval_batch_mode(SimdMode::Off, &words, &mut out);
        black_box(out[0])
    });
    b.run_elems("tanh_unit_live_scalar_batch_1k", n as u64, || {
        unit.eval_batch_mode(SimdMode::Scalar, &words, &mut out);
        black_box(out[0])
    });
    b.run_elems("tanh_unit_memo_scalar_batch_1k", n as u64, || {
        memo.eval_batch_mode(SimdMode::Scalar, &words, &mut out);
        black_box(out[0])
    });
    if avx2 {
        b.run_elems("tanh_unit_live_avx2_batch_1k", n as u64, || {
            unit.eval_batch_mode(SimdMode::Avx2, &words, &mut out);
            black_box(out[0])
        });
        b.run_elems("tanh_unit_memo_avx2_batch_1k", n as u64, || {
            memo.eval_batch_mode(SimdMode::Avx2, &words, &mut out);
            black_box(out[0])
        });
    }
    // The i32 wire-type path (what the coordinator backend calls).
    let mut out32 = vec![0i32; n];
    b.run_elems("tanh_unit_i32_batch_1k", n as u64, || {
        memo.eval_batch_i32_into(&words32, &mut out32);
        black_box(out32[0])
    });
    // Sigmoid rides the tanh kernels through its halving pre-pass.
    let sig = SigmoidUnit::new(cfg).unwrap();
    b.run_elems("sigmoid_batch_1k", n as u64, || {
        sig.eval_batch_into(&words, &mut out);
        black_box(out[0])
    });
    // Top published baselines, hoisted batch loops vs per-word calls.
    let (fi, fo) = fmt16();
    let pwl = Pwl::new(fi, fo, 64);
    let dctif = Dctif::new(fi, fo, 4, 64);
    let ralut = RangeLut::new(fi, fo, 6);
    let impls: [(&str, &dyn TanhImpl); 3] =
        [("pwl", &pwl), ("dctif", &dctif), ("ralut", &ralut)];
    for (name, imp) in impls {
        b.run_elems(&format!("{name}_batch_1k"), n as u64, || {
            imp.eval_batch_words(&words, &mut out);
            black_box(out[0])
        });
        b.run_elems(&format!("{name}_per_word_1k"), n as u64, || {
            for (o, &x) in out.iter_mut().zip(&words) {
                *o = imp.eval_word(x);
            }
            black_box(out[0])
        });
    }

    // Perf summary vs targets (DESIGN.md §9).
    println!("\n--- perf targets ---");
    if let Some(m) = b.get("tanh_unit_memo_batch_1k") {
        let tp = m.throughput().unwrap();
        println!(
            "memoized unit: {:.2e} tanh/s (target >= 1e8): {}",
            tp,
            if tp >= 1e8 { "MET" } else { "MISSED" }
        );
    }
    if let (Some(unit_m), Some(coord)) = (
        b.get("tanh_unit_memo_batch_1k"),
        b.get("coordinator_roundtrip_256w"),
    ) {
        let per_word_unit = unit_m.mean_ns / 1024.0;
        let per_word_coord = coord.mean_ns / 256.0;
        println!(
            "coordinator overhead: {:.1} ns/word vs {:.2} ns/word raw \
             (batching window dominates at low load — see EXPERIMENTS.md)",
            per_word_coord, per_word_unit
        );
    }

    // SIMD speedup: the PR's acceptance floor. Only enforced where the
    // vector path actually runs; elsewhere the skip is recorded both
    // on stdout and in the JSON artifact (ratio: null).
    let ratio = if avx2 {
        let scalar = b
            .get("tanh_unit_live_scalar_batch_1k")
            .and_then(|m| m.throughput());
        let vector = b
            .get("tanh_unit_live_avx2_batch_1k")
            .and_then(|m| m.throughput());
        match (scalar, vector) {
            (Some(s), Some(v)) if s > 0.0 => Some(v / s),
            _ => None,
        }
    } else {
        None
    };
    match ratio {
        Some(r) => {
            println!("simd live-datapath speedup (avx2/scalar): {r:.2}x");
            assert!(
                r >= 1.5,
                "AVX2 live-datapath speedup {r:.2}x is below the 1.5x floor"
            );
        }
        None => println!(
            "simd live-datapath speedup: skipped (host has no AVX2)"
        ),
    }

    // Machine-readable artifact for the CI smoke leg (cwd is rust/
    // under `cargo bench`, matching the other BENCH_* artifacts).
    let rows: Vec<Json> = b
        .results
        .iter()
        .map(|m| {
            Json::Obj(
                [
                    ("name".to_string(), Json::Str(m.name.clone())),
                    ("mean_ns".to_string(), Json::Num(m.mean_ns)),
                    (
                        "elems_per_sec".to_string(),
                        m.throughput().map_or(Json::Null, Json::Num),
                    ),
                ]
                .into_iter()
                .collect(),
            )
        })
        .collect();
    let doc = Json::Obj(
        [
            (
                "simd_mode".to_string(),
                Json::Str(simd::active().name().to_string()),
            ),
            ("avx2_host".to_string(), Json::Bool(avx2)),
            (
                "live_avx2_over_scalar".to_string(),
                ratio.map_or(Json::Null, Json::Num),
            ),
            ("kernels".to_string(), Json::Arr(rows)),
        ]
        .into_iter()
        .collect(),
    );
    std::fs::write("BENCH_throughput.json", json::write(&doc))
        .expect("write BENCH_throughput.json");
    println!("wrote BENCH_throughput.json ({} kernels)", b.results.len());
}
