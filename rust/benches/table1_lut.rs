//! Regenerates the paper's Table I: multi-bit lookup for velocity
//! factors (the 2-bit grouped LUT contents), plus the production 4-bit
//! grouped tables, and times LUT construction.

use tanh_vf::bench::Bench;
use tanh_vf::tanh::lut::{lut_tables, table1_rows};
use tanh_vf::tanh::TanhConfig;
use tanh_vf::util::table::Table;

fn main() {
    println!("=== Table I: multi-bit lookup for velocity factors ===");
    println!("(2-bit grouping; '11' rows are products of the '01'/'10' rows)\n");
    let rows = table1_rows(&TanhConfig::s3_12());
    let mut t = Table::new(&["entry", "stored word (u0.18)", "value"]);
    for (name, word, value) in rows.iter().take(12) {
        t.row(&[name.clone(), format!("{word}"), format!("{value:.9}")]);
    }
    t.row(&["...".into(), "...".into(), "...".into()]);
    println!("{}", t.render());
    println!("({} total entries across all 2-bit groups)\n", rows.len());

    println!("=== production 4-bit grouped tables (fig. 5 datapath) ===\n");
    let cfg = TanhConfig::s3_12();
    let tables = lut_tables(&cfg);
    let mut t = Table::new(&["group", "addressed bits", "entries", "ROM bits"]);
    for (g, (pos, table)) in
        cfg.group_positions().iter().zip(&tables).enumerate()
    {
        t.row(&[
            format!("LUT{g}"),
            format!("{pos:?}"),
            format!("{}", table.len()),
            format!("{}", table.len() * (cfg.lut_bits as usize + 1)),
        ]);
    }
    println!("{}", t.render());

    println!("--- timing: LUT construction (build-time cost) ---");
    let mut b = Bench::default();
    b.run("lut_tables_s3_12", || lut_tables(&TanhConfig::s3_12()));
    b.run("lut_tables_s3_5", || lut_tables(&TanhConfig::s3_5()));
}
