//! HTTP serving-path throughput: the L4 front door under closed-loop
//! load at increasing connection counts, with the direct in-process
//! router as the overhead baseline. Companion to `throughput.rs`, one
//! layer up the stack.

use std::time::Instant;

use tanh_vf::server::loadgen::{self, LoadgenConfig};
use tanh_vf::server::{parse_routes, Server, ServerConfig};

fn main() {
    let routes = parse_routes("native:s3_12,native:s3_5").unwrap();
    let srv = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 32,
            max_connections: 32,
            ..Default::default()
        },
        routes,
    )
    .unwrap();
    let addr = srv.local_addr().to_string();

    println!("== HTTP serving (closed-loop POST /v1/batch, 64 words, mixed s3_12/s3_5) ==\n");
    for conns in [1usize, 4, 16] {
        let mut cfg = LoadgenConfig::new(addr.clone(), &["s3_12", "s3_5"]);
        cfg.connections = conns;
        cfg.requests_per_connection = 400;
        cfg.words_per_request = 64;
        cfg.word_range = 128;
        let r = loadgen::run(&cfg).expect("loadgen");
        assert_eq!(r.failures, 0, "{}", r.render());
        println!("conns={conns:<3} {}", r.render());
    }

    // Baseline: the same batch shape straight into the router (no HTTP),
    // to show what the wire + parse layer costs per request.
    let direct_routes = parse_routes("native:s3_12").unwrap();
    let router =
        tanh_vf::coordinator::router::Router::start(direct_routes).unwrap();
    let words: Vec<i32> = (0..64).map(|i| (i * 31) % 128).collect();
    let n = 2000;
    let t0 = Instant::now();
    for _ in 0..n {
        router.eval_blocking("s3_12", words.clone()).unwrap();
    }
    let direct = t0.elapsed();
    println!(
        "\ndirect router baseline: {:.0} req/s ({:.1} us/req) — \
         HTTP delta above this is wire+parse overhead",
        n as f64 / direct.as_secs_f64(),
        direct.as_micros() as f64 / n as f64
    );

    println!("\n== per-route completions ==");
    for (route, snap) in srv.snapshots() {
        println!(
            "{route:<8} completed={} batches={} fill={:.2} p99={}us",
            snap.completed, snap.batches, snap.mean_batch_fill,
            snap.p99_latency_us
        );
    }
}
