//! HTTP serving-path throughput: the L4 front door under closed-loop
//! load at increasing connection counts, the direct in-process router
//! as the overhead baseline, and the reactor-vs-threaded concurrency
//! headroom comparison (same worker count, how many connections can
//! each backend sustain?). Companion to `throughput.rs`, one layer up
//! the stack. Results persist to `BENCH_http_serving.json` so the perf
//! trajectory is tracked across PRs.

use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use tanh_vf::server::cluster::{Cluster, ClusterConfig};
use tanh_vf::server::http::HttpConn;
use tanh_vf::server::loadgen::{self, LoadgenConfig};
use tanh_vf::server::{parse_routes, Server, ServerConfig};
use tanh_vf::util::json::{self, Json};

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Reserve `n` distinct loopback addresses (cluster fronts must know
/// each other's address before any of them starts).
fn free_addrs(n: usize) -> Vec<String> {
    let listeners: Vec<TcpListener> = (0..n)
        .map(|_| TcpListener::bind("127.0.0.1:0").expect("bind"))
        .collect();
    listeners
        .iter()
        .map(|l| l.local_addr().expect("addr").to_string())
        .collect()
}

/// Open `n` connections, hold them all open, then round-trip one
/// `GET /health` on each: the count of 200s is the number of
/// *simultaneously sustained* connections the backend admits.
fn sustained_connections(addr: &str, n: usize) -> usize {
    let mut conns: Vec<HttpConn> = Vec::new();
    for _ in 0..n {
        let Ok(s) = TcpStream::connect(addr) else { break };
        let _ = s.set_nodelay(true);
        let _ = s.set_read_timeout(Some(Duration::from_secs(10)));
        conns.push(HttpConn::new(s));
    }
    let mut ok = 0usize;
    for c in conns.iter_mut() {
        if c.write_request("GET", "/health", b"").is_err() {
            continue;
        }
        if let Ok((200, _, _)) = c.read_response(1 << 20) {
            ok += 1;
        }
    }
    ok
}

fn main() {
    // -- closed-loop throughput on the default (reactor) backend ------
    let routes = parse_routes("native:s3_12,native:s3_5").unwrap();
    let srv = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 32,
            max_connections: 128,
            ..Default::default()
        },
        routes,
    )
    .unwrap();
    let addr = srv.local_addr().to_string();

    println!("== HTTP serving (closed-loop POST /v1/batch, 64 words, mixed s3_12/s3_5) ==\n");
    let mut closed_loop = Vec::new();
    for conns in [1usize, 4, 16, 64] {
        let mut cfg = LoadgenConfig::new(addr.clone(), &["s3_12", "s3_5"]);
        cfg.connections = conns;
        cfg.requests_per_connection = 400;
        cfg.words_per_request = 64;
        cfg.word_range = 128;
        let r = loadgen::run(&cfg).expect("loadgen");
        assert_eq!(r.failures, 0, "{}", r.render());
        println!("conns={conns:<3} {}", r.render());
        closed_loop.push(obj(vec![
            ("connections", Json::Num(conns as f64)),
            ("report", r.to_json()),
        ]));
    }

    // Baseline: the same batch shape straight into the router (no HTTP),
    // to show what the wire + parse layer costs per request.
    let direct_routes = parse_routes("native:s3_12").unwrap();
    let router =
        tanh_vf::coordinator::router::Router::start(direct_routes).unwrap();
    let words: Vec<i32> = (0..64).map(|i| (i * 31) % 128).collect();
    let n = 2000;
    let t0 = Instant::now();
    for _ in 0..n {
        router.eval_blocking("s3_12", words.clone()).unwrap();
    }
    let direct = t0.elapsed();
    let direct_rps = n as f64 / direct.as_secs_f64();
    println!(
        "\ndirect router baseline: {direct_rps:.0} req/s ({:.1} us/req) — \
         HTTP delta above this is wire+parse overhead",
        direct.as_micros() as f64 / n as f64
    );

    println!("\n== per-route completions ==");
    let mut route_snaps: BTreeMap<String, Json> = BTreeMap::new();
    for (route, snap) in srv.snapshots() {
        println!(
            "{route:<8} completed={} batches={} fill={:.2} p99={}us",
            snap.completed, snap.batches, snap.mean_batch_fill,
            snap.p99_latency_us
        );
        route_snaps.insert(
            route,
            obj(vec![
                ("completed", Json::Num(snap.completed as f64)),
                ("batches", Json::Num(snap.batches as f64)),
                ("p99_us", Json::Num(snap.p99_latency_us as f64)),
            ]),
        );
    }
    drop(srv);

    // -- concurrency headroom: reactor vs thread-per-connection -------
    // Equal worker count; the threaded backend's capacity is
    // min(max_connections, workers) while the reactor's is
    // max_connections alone. The acceptance bar is >2x.
    const WORKERS: usize = 4;
    const MAX_CONNS: usize = 64;
    const ATTEMPT: usize = 32;
    println!(
        "\n== sustained concurrent connections (workers={WORKERS}, \
         max-conns={MAX_CONNS}, attempting {ATTEMPT}) =="
    );
    let mut sustained = BTreeMap::new();
    for (label, event_loop) in [("threaded", false), ("reactor", true)] {
        let srv = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                workers: WORKERS,
                max_connections: MAX_CONNS,
                event_loop,
                ..Default::default()
            },
            parse_routes("native:s3_5").unwrap(),
        )
        .unwrap();
        let got = sustained_connections(&srv.local_addr().to_string(), ATTEMPT);
        println!("{label:<9} {got}/{ATTEMPT} connections served");
        sustained.insert(label.to_string(), got);
    }
    let threaded_ok = sustained["threaded"].max(1);
    let reactor_ok = sustained["reactor"];
    let ratio = reactor_ok as f64 / threaded_ok as f64;
    println!("reactor/threaded sustained-connection ratio: {ratio:.1}x");
    assert!(
        ratio > 2.0,
        "reactor must sustain >2x the threaded backend's connections \
         at equal worker count (got {ratio:.1}x)"
    );

    // -- cluster scaling: 3 consistent-hash fronts vs a single node ---
    // Every front serves the same route table; model names shard
    // across the ring, so each request is either answered locally or
    // proxied one hop to its owner. The persisted point tracks what
    // the cluster tier costs/buys at equal total connection count.
    const NODES: usize = 3;
    const CLUSTER_CONNS: usize = 24;
    const CLUSTER_REQS: usize = 150;
    println!(
        "\n== cluster scaling ({NODES} fronts, {CLUSTER_CONNS} conns, \
         mixed s3_12/s3_5) =="
    );
    let single = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 16,
            max_connections: 128,
            ..Default::default()
        },
        parse_routes("native:s3_12,native:s3_5").unwrap(),
    )
    .unwrap();
    let mut cfg =
        LoadgenConfig::new(single.local_addr().to_string(), &["s3_12", "s3_5"]);
    cfg.connections = CLUSTER_CONNS;
    cfg.requests_per_connection = CLUSTER_REQS;
    cfg.words_per_request = 64;
    cfg.word_range = 128;
    let single_report = loadgen::run(&cfg).expect("single-node loadgen");
    assert_eq!(single_report.failures, 0, "{}", single_report.render());
    println!("single-node {}", single_report.render());
    drop(single);

    // Reserved ports can be snatched between release and re-bind
    // (TOCTOU); retry with a fresh group like the e2e helper does.
    let (fronts, addrs) = {
        let mut made: Option<(Vec<Server>, Vec<String>)> = None;
        'attempt: for _ in 0..5 {
            let addrs = free_addrs(NODES);
            let mut fronts = Vec::with_capacity(NODES);
            for i in 0..NODES {
                let peers: Vec<String> = addrs
                    .iter()
                    .enumerate()
                    .filter(|&(j, _)| j != i)
                    .map(|(_, a)| a.clone())
                    .collect();
                match Server::start_cluster(
                    ServerConfig {
                        addr: addrs[i].clone(),
                        workers: 16,
                        max_connections: 128,
                        ..Default::default()
                    },
                    parse_routes("native:s3_12,native:s3_5").unwrap(),
                    ClusterConfig {
                        advertise: addrs[i].clone(),
                        peers,
                        probe_interval: Duration::from_millis(250),
                        ..Default::default()
                    },
                ) {
                    Ok(srv) => fronts.push(srv),
                    Err(_) => continue 'attempt, // port stolen; regroup
                }
            }
            made = Some((fronts, addrs));
            break;
        }
        made.expect("could not bind a free port group for the cluster")
    };
    let mut ccfg = LoadgenConfig::new(addrs[0].clone(), &["s3_12", "s3_5"]);
    ccfg.addrs = addrs.clone();
    ccfg.connections = CLUSTER_CONNS;
    ccfg.requests_per_connection = CLUSTER_REQS;
    ccfg.words_per_request = 64;
    ccfg.word_range = 128;
    let cluster_report = loadgen::run(&ccfg).expect("cluster loadgen");
    assert_eq!(cluster_report.failures, 0, "{}", cluster_report.render());
    println!("cluster     {}", cluster_report.render());

    // -- skewed popularity: the same cluster under zipf(1.1) ----------
    // The uniform run above cycles models evenly; this one concentrates
    // demand on the first model (the hot-route controller's target
    // workload). Both rows persist so the trajectory records what skew
    // costs/buys; the assertions are monotone-sanity, not a ranking —
    // relative throughput under skew is hardware- and load-dependent.
    const ZIPF_S: f64 = 1.1;
    let mut zcfg = ccfg.clone();
    zcfg.zipf_s = ZIPF_S;
    zcfg.seed = 43;
    let zipf_report = loadgen::run(&zcfg).expect("zipf loadgen");
    assert_eq!(zipf_report.failures, 0, "{}", zipf_report.render());
    println!("zipf({ZIPF_S}) {}", zipf_report.render());
    for (label, r) in
        [("uniform", &cluster_report), ("zipf", &zipf_report)]
    {
        assert!(r.req_per_s() > 0.0, "{label}: no throughput measured");
        assert!(
            r.p50_us <= r.p95_us && r.p95_us <= r.max_us,
            "{label}: latency quantiles out of order ({})",
            r.render()
        );
    }

    let (mut proxied, mut local_hits) = (0u64, 0u64);
    for f in &fronts {
        let st = &f.cluster().expect("cluster mode").stats;
        proxied += st.proxied.load(std::sync::atomic::Ordering::Relaxed);
        local_hits += st.local.load(std::sync::atomic::Ordering::Relaxed);
    }
    let scaling_ratio =
        cluster_report.req_per_s() / single_report.req_per_s().max(1e-9);
    println!(
        "cluster/single rps ratio: {scaling_ratio:.2}x \
         ({proxied} proxied, {local_hits} local)"
    );
    assert!(
        proxied > 0 && local_hits > 0,
        "cluster run must exercise both the local and the proxy path"
    );
    drop(fronts);

    // -- proxy connection pooling: pooled vs per-request connect ------
    // The same forward path against the same peer; the only variable
    // is the pool (idle cap 4 vs 0 = fresh TcpStream::connect every
    // request). The pooled point must measurably win — reuse saves a
    // TCP handshake per forward.
    const FWD_N: usize = 400;
    println!("\n== proxy forward latency: pooled vs per-request connect ==");
    let peer = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 8,
            max_connections: 64,
            ..Default::default()
        },
        parse_routes("native:s3_5").unwrap(),
    )
    .unwrap();
    let peer_addr = peer.local_addr().to_string();
    let fwd_body = br#"{"model":"s3_5","word":3}"#;
    let mut fwd_stats: BTreeMap<&str, (f64, f64)> = BTreeMap::new();
    for (label, idle) in [("unpooled", 0usize), ("pooled", 4)] {
        let cl = Cluster::start(ClusterConfig {
            advertise: "127.0.0.1:1".into(),
            peers: vec![peer_addr.clone()],
            probe_interval: Duration::from_secs(3600),
            pool_idle_per_peer: idle,
            ..Default::default()
        })
        .unwrap();
        for _ in 0..20 {
            // Warm the peer's route tables and the TCP stack.
            cl.forward(&peer_addr, "/v1/eval", fwd_body, &[]).unwrap();
        }
        let mut lats: Vec<u64> = Vec::with_capacity(FWD_N);
        for _ in 0..FWD_N {
            let t = Instant::now();
            let resp = cl.forward(&peer_addr, "/v1/eval", fwd_body, &[]).unwrap();
            assert_eq!(resp.status, 200);
            lats.push(t.elapsed().as_nanos() as u64);
        }
        lats.sort_unstable();
        let mean =
            lats.iter().sum::<u64>() as f64 / lats.len() as f64 / 1000.0;
        let p50 = lats[lats.len() / 2] as f64 / 1000.0;
        println!("{label:<9} mean {mean:.1} us, p50 {p50:.1} us per forward");
        if label == "pooled" {
            let hits =
                cl.pool.stats.hits.load(std::sync::atomic::Ordering::Relaxed);
            assert!(
                hits as usize >= FWD_N,
                "pooled run must actually reuse connections ({hits} hits)"
            );
        }
        fwd_stats.insert(label, (mean, p50));
        cl.stop();
    }
    drop(peer);
    let (pooled_mean, pooled_p50) = fwd_stats["pooled"];
    let (unpooled_mean, unpooled_p50) = fwd_stats["unpooled"];
    let fwd_speedup = unpooled_mean / pooled_mean;
    println!("pooled/unpooled forward speedup: {fwd_speedup:.2}x");
    assert!(
        fwd_speedup > 1.05,
        "pooled forwards must measurably beat per-request connect \
         (got {fwd_speedup:.2}x)"
    );

    // -- persist ------------------------------------------------------
    let out = obj(vec![
        ("bench", Json::Str("http_serving".into())),
        ("closed_loop", Json::Arr(closed_loop)),
        ("direct_router_rps", Json::Num(direct_rps)),
        (
            "routes",
            Json::Obj(route_snaps),
        ),
        (
            "concurrency_headroom",
            obj(vec![
                ("workers", Json::Num(WORKERS as f64)),
                ("max_connections", Json::Num(MAX_CONNS as f64)),
                ("attempted", Json::Num(ATTEMPT as f64)),
                (
                    "threaded_sustained",
                    Json::Num(sustained["threaded"] as f64),
                ),
                ("reactor_sustained", Json::Num(reactor_ok as f64)),
                ("ratio", Json::Num(ratio)),
            ]),
        ),
        (
            "cluster_scaling",
            obj(vec![
                ("nodes", Json::Num(NODES as f64)),
                ("connections", Json::Num(CLUSTER_CONNS as f64)),
                ("single_node", single_report.to_json()),
                ("cluster", cluster_report.to_json()),
                ("rps_ratio", Json::Num(scaling_ratio)),
                ("proxied_requests", Json::Num(proxied as f64)),
                ("local_requests", Json::Num(local_hits as f64)),
            ]),
        ),
        (
            "skewed_profile",
            obj(vec![
                ("zipf_s", Json::Num(ZIPF_S)),
                ("uniform", cluster_report.to_json()),
                ("zipf", zipf_report.to_json()),
            ]),
        ),
        (
            "proxy_pooling",
            obj(vec![
                ("forwards", Json::Num(FWD_N as f64)),
                ("pooled_mean_us", Json::Num(pooled_mean)),
                ("pooled_p50_us", Json::Num(pooled_p50)),
                ("unpooled_mean_us", Json::Num(unpooled_mean)),
                ("unpooled_p50_us", Json::Num(unpooled_p50)),
                ("speedup", Json::Num(fwd_speedup)),
            ]),
        ),
    ]);
    let path = "BENCH_http_serving.json";
    std::fs::write(path, json::write(&out)).expect("write bench json");
    println!("\nwrote {path}");
}
