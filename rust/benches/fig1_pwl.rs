//! Regenerates the paper's fig. 1: tanh and its piecewise-linear
//! approximation. Emits the series as CSV (for plotting) and prints a
//! coarse ASCII rendering plus the approximation-gap summary.

use tanh_vf::baselines::pwl::fig1_series;

fn main() {
    let segments = 8;
    let series = fig1_series(segments, 161);

    // CSV artifact for plotting.
    let out = tanh_vf::util::repo_path("target/fig1_tanh_pwl.csv");
    let mut csv = String::from("x,tanh,pwl\n");
    for (x, t, p) in &series {
        csv.push_str(&format!("{x:.4},{t:.6},{p:.6}\n"));
    }
    std::fs::create_dir_all(out.parent().unwrap()).unwrap();
    std::fs::write(&out, &csv).unwrap();
    println!("wrote {} ({} points)\n", out.display(), series.len());

    // ASCII rendering (paper fig. 1's visual).
    println!("fig. 1 — tanh (*) and {segments}-segment PWL (o), x in [-4, 4]:\n");
    let height = 21;
    for row in 0..height {
        let y = 1.0 - 2.0 * row as f64 / (height - 1) as f64;
        let mut line: Vec<char> = vec![' '; 81];
        for (i, &(_, t, p)) in series.iter().enumerate().step_by(2) {
            let col = i / 2;
            if (p - y).abs() < 0.05 {
                line[col] = 'o';
            }
            if (t - y).abs() < 0.05 {
                line[col] = '*';
            }
        }
        let axis = if (y).abs() < 0.026 { '-' } else { '|' };
        println!("{y:+.2} {axis} {}", line.iter().collect::<String>());
    }

    // Gap summary: where PWL deviates most (the knee).
    let (wx, gap) = series
        .iter()
        .map(|&(x, t, p)| (x, (t - p).abs()))
        .fold((0.0, 0.0), |acc, v| if v.1 > acc.1 { v } else { acc });
    println!("\nmax |tanh - PWL| = {gap:.4} at x = {wx:+.3} (knee region)");
    assert!(gap < 0.1, "PWL gap out of expected band");
}
