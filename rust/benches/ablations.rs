//! Ablations over the paper's design choices (§IV.B), plus the §II/§V
//! baseline comparison:
//!
//!  1. published method (fig. 3, eq. 3 residual) vs optimized datapath
//!  2. bit-shuffled vs sequential LUT addressing
//!  3. LUT group size (registers vs grouped ROMs)
//!  4. NR seed constant choice
//!  5. LUT precision scaling ("18-bit precision is enough")
//!  6. baseline accuracy-vs-cost table
//!  7. datapath PPA vs a same-accuracy pure-LUT design

use tanh_vf::analysis::{exhaustive_error, TanhImpl};
use tanh_vf::baselines;
use tanh_vf::gates::CellClass;
use tanh_vf::synth::ppa::ppa_for;
use tanh_vf::tanh::published::{published_max_error, PublishedConfig};
use tanh_vf::tanh::{Subtractor, TanhConfig, TanhUnit};
use tanh_vf::util::table::{sci, Table};

fn err_of(cfg: TanhConfig) -> f64 {
    exhaustive_error(&TanhUnit::new(cfg).unwrap()).max_abs
}

fn main() {
    // --- 1. published vs optimized (the §IV.B.1 improvement) -----------
    println!("== ablation 1: published method (eq. 3 tail) vs optimized ==\n");
    let mut t = Table::new(&["variant", "max error", "last-stage muls"]);
    for thr in [5, 7, 9] {
        let pc = PublishedConfig { base: TanhConfig::s3_12(), threshold_exp: thr };
        t.row(&[
            format!("published, registers >= 2^-{thr} ({})", pc.register_count()),
            sci(published_max_error(&pc)),
            "2 extra".into(),
        ]);
    }
    t.row(&[
        "optimized (all bits exact, fig. 5)".into(),
        sci(err_of(TanhConfig::s3_12())),
        "0 extra".into(),
    ]);
    println!("{}", t.render());

    // --- 2. shuffle vs sequential addressing ---------------------------
    println!("== ablation 2: bit-shuffled vs sequential LUT addressing ==\n");
    let mut t = Table::new(&["addressing", "L=18 err", "L=16 err", "L=14 err"]);
    for (name, shuffle) in [("shuffled (paper)", true), ("sequential", false)] {
        let mut row = vec![name.to_string()];
        for l in [18u32, 16, 14] {
            let mut cfg = TanhConfig::s3_12().with_shuffle(shuffle);
            cfg.lut_bits = l;
            cfg.mult_bits = cfg.mult_bits.min(l + 1).min(16);
            row.push(sci(err_of(cfg)));
        }
        t.row(&row);
    }
    println!("{}", t.render());
    println!("(shuffling matters more as LUT precision shrinks — §IV.B.3)\n");

    // --- 3. group size --------------------------------------------------
    println!("== ablation 3: LUT group size (muls vs ROM bits) ==\n");
    let mut t = Table::new(&[
        "group", "chain muls", "ROM bits", "max err", "SVT area um2",
    ]);
    for g in 1..=5u32 {
        let cfg = TanhConfig::s3_12().with_group(g);
        let rom: u64 = cfg
            .group_positions()
            .iter()
            .map(|p| (1u64 << p.len()) * 19)
            .sum();
        t.row(&[
            format!("{g}"),
            format!("{}", cfg.num_groups() - 1),
            format!("{rom}"),
            sci(err_of(cfg)),
            format!("{:.0}", ppa_for(&cfg, CellClass::Svt, 2).area_um2),
        ]);
    }
    println!("{}", t.render());

    // --- 4. NR stages beyond the paper ----------------------------------
    println!("== ablation 4: NR stage count ==\n");
    let mut t = Table::new(&["NR stages", "max err", "levels (1-stage)"]);
    for nr in [1u32, 2, 3, 4] {
        let cfg = TanhConfig::s3_12().with_nr(nr);
        t.row(&[
            format!("{nr}"),
            sci(err_of(cfg)),
            format!("{}", ppa_for(&cfg, CellClass::Svt, 1).logic_levels),
        ]);
    }
    println!("{}", t.render());

    // --- 5. LUT precision ("18 bits is enough for 1-bit error") --------
    println!("== ablation 5: LUT precision L at s3.12 -> s.15 ==\n");
    let mut t = Table::new(&["L", "max err", "err (lsb)"]);
    for l in [15u32, 16, 17, 18, 20, 22] {
        let mut cfg = TanhConfig::s3_12();
        cfg.lut_bits = l;
        let e = err_of(cfg);
        t.row(&[
            format!("{l}"),
            sci(e),
            format!("{:.2}", e / 2f64.powi(-15)),
        ]);
    }
    println!("{}", t.render());

    // --- 6. baselines ----------------------------------------------------
    println!("== baseline comparison (§II / §V), 16-bit point ==\n");
    let mut t = Table::new(&[
        "implementation", "max err", "LUT bits", "muls", "adders",
    ]);
    let unit = TanhUnit::new(TanhConfig::s3_12()).unwrap();
    let mut impls: Vec<Box<dyn TanhImpl>> = baselines::suite16();
    impls.insert(0, Box::new(unit));
    for imp in &impls {
        let e = exhaustive_error(imp.as_ref());
        let c = imp.cost();
        t.row(&[
            imp.name(),
            sci(e.max_abs),
            format!("{}", c.lut_bits),
            format!("{}", c.multipliers),
            format!("{}", c.adders),
        ]);
    }
    println!("{}", t.render());

    // --- 7. sanity assertions on the ablation shapes --------------------
    let e_opt = err_of(TanhConfig::s3_12());
    let e_pub = published_max_error(&PublishedConfig::default());
    assert!(e_opt < e_pub, "optimized must beat published");
    let e_ones = err_of(TanhConfig::s3_12().with_subtractor(Subtractor::Ones));
    assert!((e_ones - e_opt).abs() < 5e-5, "1's vs 2's must be marginal");
    println!("ablation shape assertions passed.");
}
