//! Regenerates the paper's Table IV (PPA for the 8-bit flavours: s3.5
//! input, s.7 output) from the synthesis model.

use tanh_vf::gates::CellClass;
use tanh_vf::synth::ppa::ppa_for;
use tanh_vf::tanh::TanhConfig;
use tanh_vf::util::table::Table;

// Paper Table IV rows: (cells, latency, area, leak uW, fmax MHz, levels)
const PAPER: &[(&str, u32, f64, f64, f64, u32)] = &[
    ("SVT", 1, 764.37, 0.81, 254.0, 97),
    ("LVT", 1, 568.99, 24.19, 303.0, 95),
    ("SVT", 2, 885.29, 0.99, 364.0, 74),
    ("LVT", 2, 877.82, 51.67, 715.0, 70),
    ("SVT", 7, 995.60, 1.08, 1532.0, 14),
    ("LVT", 7, 934.82, 49.04, 2985.0, 13),
];

fn main() {
    println!("=== Table IV: PPA, s3.5 -> s.7 (modelled vs paper) ===\n");
    let cfg = TanhConfig::s3_5();
    let mut t = Table::new(&[
        "Cells", "Clk", "Area um2 (model|paper)", "Leak uW (model|paper)",
        "Fmax MHz (model|paper)", "Levels (model|paper)",
    ]);
    for &(cells, clk, p_area, p_leak, p_fmax, p_lvl) in PAPER {
        let class = if cells == "SVT" { CellClass::Svt } else { CellClass::Lvt };
        let r = ppa_for(&cfg, class, clk);
        t.row(&[
            cells.to_string(),
            format!("{clk}"),
            format!("{:.0} | {:.0}", r.area_um2, p_area),
            format!("{:.2} | {:.2}", r.leakage_uw, p_leak),
            format!("{:.0} | {:.0}", r.fmax_mhz, p_fmax),
            format!("{} | {}", r.logic_levels, p_lvl),
        ]);
    }
    println!("{}", t.render());

    // The headline cross-table shape: 8-bit is several times smaller
    // than 16-bit at the same stage count.
    let a16 = ppa_for(&TanhConfig::s3_12(), CellClass::Svt, 1).area_um2;
    let a8 = ppa_for(&cfg, CellClass::Svt, 1).area_um2;
    println!(
        "16-bit/8-bit area ratio (SVT, 1 stage): {:.1}x (paper: 4.9x)",
        a16 / a8
    );
    assert!(a16 / a8 > 2.5, "scalability shape violated");
}
