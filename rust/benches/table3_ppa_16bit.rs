//! Regenerates the paper's Table III (PPA for the 16-bit flavours) from
//! the synthesis model, printed side by side with the paper's numbers,
//! and times the synthesis-model evaluation itself.

use tanh_vf::bench::Bench;
use tanh_vf::gates::CellClass;
use tanh_vf::synth::ppa::ppa_for;
use tanh_vf::tanh::TanhConfig;
use tanh_vf::util::table::Table;

// Paper Table III rows: (cells, latency, area, leak uW, fmax MHz, levels)
const PAPER: &[(&str, u32, f64, f64, f64, u32)] = &[
    ("SVT", 1, 3748.28, 4.20, 188.0, 135),
    ("LVT", 1, 2600.34, 119.33, 302.0, 111),
    ("SVT", 2, 3400.43, 3.53, 258.0, 95),
    ("LVT", 2, 3367.16, 180.67, 511.0, 86),
    ("SVT", 7, 3688.98, 3.92, 1176.0, 25),
    ("LVT", 7, 3147.68, 146.67, 2134.0, 17),
];

fn main() {
    println!("=== Table III: PPA, s3.12 -> s.15 (modelled vs paper) ===\n");
    let cfg = TanhConfig::s3_12();
    let mut t = Table::new(&[
        "Cells", "Clk", "Area um2 (model|paper)", "Leak uW (model|paper)",
        "Fmax MHz (model|paper)", "Levels (model|paper)",
    ]);
    for &(cells, clk, p_area, p_leak, p_fmax, p_lvl) in PAPER {
        let class = if cells == "SVT" { CellClass::Svt } else { CellClass::Lvt };
        let r = ppa_for(&cfg, class, clk);
        t.row(&[
            cells.to_string(),
            format!("{clk}"),
            format!("{:.0} | {:.0}", r.area_um2, p_area),
            format!("{:.2} | {:.2}", r.leakage_uw, p_leak),
            format!("{:.0} | {:.0}", r.fmax_mhz, p_fmax),
            format!("{} | {}", r.logic_levels, p_lvl),
        ]);
    }
    println!("{}", t.render());

    // Shape checks the model must reproduce (reported, then asserted).
    let f = |c, s| ppa_for(&cfg, c, s).fmax_mhz;
    let ratio17 = f(CellClass::Svt, 7) / f(CellClass::Svt, 1);
    println!("fmax 1->7 stage ratio: {:.2}x (paper: 6.25x)", ratio17);
    let lvt_leak = ppa_for(&cfg, CellClass::Lvt, 1).leakage_uw
        / ppa_for(&cfg, CellClass::Svt, 1).leakage_uw;
    println!("LVT/SVT leakage ratio: {:.0}x (paper: ~28x)", lvt_leak);
    assert!(ratio17 > 3.5 && lvt_leak > 20.0, "PPA shape violated");

    println!("\n--- timing of the synthesis model ---");
    let mut b = Bench::default();
    b.run("ppa_model_full_table", || {
        for clk in [1u32, 2, 7] {
            for class in [CellClass::Svt, CellClass::Lvt] {
                std::hint::black_box(ppa_for(&cfg, class, clk));
            }
        }
    });
}
